"""Extension of /tmp/mirror.py: golden-line rendering, validation against
rust/tests/golden/timelines.txt, plus mirrors of the PLANNED changes:
per-node intra links, dispatch/combine phase split, routed byte matrices,
Placement layouts, Rng port."""
import os
import sys
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from dataclasses import replace
from mirror import *
from mirror import SCENARIOS

MASK = (1 << 64) - 1


class Rng:
    def __init__(self, seed):
        self.state = (seed + 0x9E3779B97F4A7C15) & MASK

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return (z ^ (z >> 31)) & MASK

    def next_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        return self.next_u64() % n

    def range_f64(self, lo, hi):
        return lo + self.next_f64() * (hi - lo)


# ---------------------------------------------------------------- golden

def resource_token(r):
    kind = r[0]
    if kind == 'compute':
        return f'c{r[1]}'
    if kind == 'comm':
        return f'm{r[1]}'
    if kind == 'link':
        return f'l{r[1]}'
    if kind == 'h2d':
        return f'h{r[1]}'
    if kind == 'd2h':
        return f'd{r[1]}'
    return 'f'


# When a list, render_line records every (name, sim) it renders — set by
# corpus_sims9() so the PR9 analysis layer replays the exact golden corpus.
_COLLECT9 = None


def render_line(name, sim):
    if _COLLECT9 is not None:
        _COLLECT9.append((name, sim))
    spans = sim.run()
    makespan = max((s[4] for s in spans), default=0.0)
    spans = sorted(spans, key=lambda s: (s[3], s[0]))
    toks = [f'{s[1]}@{resource_token(s[2])}@{s[3]:.6f}' for s in spans]
    return f'{name} | makespan {makespan:.6f} | ' + ' '.join(toks)


def dyadic_costs():
    return BlockCosts(1.0, 0.75, 0.75, 0.0625, 0.0625, 0.0625, 0.5, 0.8125)


def dyadic_fleet():
    fast = dyadic_costs()
    slow = BlockCosts(2.0, 1.5, 1.5, 0.125, 0.125, 0.125, 1.0, 0.8125)
    return TopoCosts([replace(fast), fast, replace(slow), slow],
                     [0.25] * 4, [0.5] * 2, 2)


def kind_label(kind):
    t, k = kind
    if t == 'std':
        return f'Top{k}'
    if t == 'shared':
        return 'Top1+SE1'
    return 'ScMoE' if k == 1 else f'ScMoE-{k}'


def generate_seed_lines():
    c = dyadic_costs()
    lines = []
    kinds = [('std', 1), ('std', 2), ('std', 3), ('shared', 1),
             ('scmoe', 1), ('scmoe', 2)]
    for kind in kinds:
        if kind[0] == 'std':
            strategies = [('seq',), ('pipe', 2), ('pipe', 4)]
        elif kind[0] == 'shared':
            strategies = [('seq',), ('pipe', 1), ('pipe', 2)]
        else:
            strategies = [('seq',), ('pipe', 2)]
        for strategy in strategies:
            if strategy[0] == 'seq':
                slabel = 'seq'
            else:
                slabel = f'pipe{strategy[1]}'
            name = f'{kind_label(kind)}/{slabel}'
            lines.append(render_line(name, build_pair_schedule(c, kind, strategy, 0)))
        if kind[0] == 'scmoe':
            for slot in range(4):
                s = build_pair_schedule(c, kind, ('overlap',), slot)
                lines.append(render_line(f'{kind_label(kind)}/overlap-s{slot}', s))
            for slot in range(4):
                s = build_pair_schedule(c, kind, ('overlap-pipe', 2), slot)
                lines.append(render_line(
                    f'{kind_label(kind)}/overlap+pipe2-s{slot}', s))
    tf = dyadic_fleet()
    lines.append(render_line('fleet:Top2/seq',
                             build_pair_schedule_topo(tf, ('std', 2), ('seq',), 0)))
    lines.append(render_line('fleet:Top2/pipe2',
                             build_pair_schedule_topo(tf, ('std', 2), ('pipe', 2), 0)))
    for slot in range(4):
        lines.append(render_line(
            f'fleet:ScMoE/overlap-s{slot}',
            build_pair_schedule_topo(tf, ('scmoe', 1), ('overlap',), slot)))
    return lines


def validate_seed_golden():
    golden_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                               '..', '..', 'rust', 'tests', 'golden', 'timelines.txt')
    golden = [l for l in open(golden_path).read().splitlines()
              if l.strip() and not l.startswith('#')]
    current = generate_seed_lines()
    golden = golden[:len(current)]  # routed lines are validated by __main__
    bad = 0
    for g, cu in zip(golden, current):
        if g != cu:
            bad += 1
            print('- ' + g)
            print('+ ' + cu)
    print(f'seed golden: {len(golden)} lines, {bad} mismatches')
    return bad == 0


# ------------------------------------------- planned: per-node intra links

def a2a_time_pn(bytes_, n_devices, devices_per_node, intra_links, inter):
    n_nodes = n_devices // devices_per_node
    node_of = lambda d: d // devices_per_node
    worst_dev = 0.0
    for src in range(n_devices):
        out_bytes = 0
        msgs = 0
        for dst in range(n_devices):
            if dst == src:
                continue
            b = bytes_[src * n_devices + dst]
            if b > 0:
                out_bytes += b
                msgs += 1
        l = intra_links[node_of(src)]
        t = l.alpha * float(msgs) + float(out_bytes) / l.beta
        worst_dev = max(worst_dev, t)
    worst_node = 0.0
    if inter is not None and n_nodes > 1:
        for node in range(n_nodes):
            cross = 0
            for src in range(n_devices):
                if node_of(src) != node:
                    continue
                for dst in range(n_devices):
                    if node_of(dst) != node:
                        cross += bytes_[src * n_devices + dst]
            if cross > 0:
                worst_node = max(worst_node, inter.alpha + float(cross) / inter.beta)
    return max(worst_dev, worst_node)


def a2a_decompose_pn(bytes_, n_devices, devices_per_node, intra_links, inter):
    n_nodes = n_devices // devices_per_node
    node_of = lambda d: d // devices_per_node
    split = inter is not None and n_nodes > 1
    intra_phase = []
    for src in range(n_devices):
        out_bytes = 0
        msgs = 0
        for dst in range(n_devices):
            if dst == src or (split and node_of(dst) != node_of(src)):
                continue
            b = bytes_[src * n_devices + dst]
            if b > 0:
                out_bytes += b
                msgs += 1
        l = intra_links[node_of(src)]
        intra_phase.append(l.alpha * float(msgs) + float(out_bytes) / l.beta)
    inter_phase = []
    if split:
        for node in range(n_nodes):
            cross = 0
            for src in range(n_devices):
                if node_of(src) != node:
                    continue
                for dst in range(n_devices):
                    if node_of(dst) != node:
                        cross += bytes_[src * n_devices + dst]
            inter_phase.append(inter.alpha + float(cross) / inter.beta
                               if cross > 0 else 0.0)
    return intra_phase, inter_phase


class TopoCosts2(TopoCosts):
    """TopoCosts with the planned combine-direction phase vectors."""

    def __init__(self, per_device, a2a_intra_k1, a2a_inter_k1, devices_per_node,
                 intra_c=None, inter_c=None):
        super().__init__(per_device, a2a_intra_k1, a2a_inter_k1, devices_per_node)
        self.a2a_intra_c_k1 = intra_c or []
        self.a2a_inter_c_k1 = inter_c or []

    def a2a_intra_c(self, d, k):
        v = self.a2a_intra_c_k1 if self.a2a_intra_c_k1 else self.a2a_intra_k1
        return v[d] * float(k)

    def a2a_inter_c(self, n, k):
        v = self.a2a_inter_c_k1 if self.a2a_inter_c_k1 else self.a2a_inter_k1
        return v[n] * float(k)


# monkey-patch base TopoCosts with symmetric fallbacks so existing builders
# in mirror.py can be reused once edited; instead we re-define the builders
# below with combine-aware phases, mirroring the planned Rust edit.
TopoCosts.a2a_intra_c = lambda self, d, k: (
    (self.a2a_intra_c_k1 if getattr(self, 'a2a_intra_c_k1', []) else
     self.a2a_intra_k1)[d] * float(k))
TopoCosts.a2a_inter_c = lambda self, n, k: (
    (self.a2a_inter_c_k1 if getattr(self, 'a2a_inter_c_k1', []) else
     self.a2a_inter_k1)[n] * float(k))


import mirror as _m


def _patch_builders_for_combine():
    """Rewrite the three topo builders to use a2a_intra_c/a2a_inter_c for
    A2A-C tasks, mirroring the planned Rust change."""
    src = open(os.path.join(os.path.dirname(os.path.abspath(__file__)), 'mirror.py')).read()
    # sequential: comb uses tc.a2a_intra(d, k) -> tc.a2a_intra_c(d, k)
    # we patch by executing modified source in a new namespace
    src = src.replace(
        'comb.append(sim.add("A2A-C", comm(d), tc.a2a_intra(d, k), [experts[d]]))',
        'comb.append(sim.add("A2A-C", comm(d), tc.a2a_intra_c(d, k), [experts[d]]))')
    src = src.replace(
        'comb.append(sim.add("A2A-Cx", link(node), tc.a2a_inter(node, k), deps))',
        'comb.append(sim.add("A2A-Cx", link(node), tc.a2a_inter_c(node, k), deps))')
    src = src.replace(
        'combines.append(sim.add(f"A2A-C{i}", comm(d), tc.a2a_intra(d, k) / fc,\n'
        '                                    [experts_i[d]]))',
        'combines.append(sim.add(f"A2A-C{i}", comm(d), tc.a2a_intra_c(d, k) / fc,\n'
        '                                    [experts_i[d]]))')
    src = src.replace(
        'combines.append(sim.add(f"A2A-Cx{i}", link(node),\n'
        '                                    tc.a2a_inter(node, k) / fc, deps))',
        'combines.append(sim.add(f"A2A-Cx{i}", link(node),\n'
        '                                    tc.a2a_inter_c(node, k) / fc, deps))')
    src = src.replace(
        'combines.append(sim.add(f"A2A-C{i}", comm(d), tc.a2a_intra(d, k) / fc,\n'
        '                                    [experts_by_dev[d][i]]))',
        'combines.append(sim.add(f"A2A-C{i}", comm(d), tc.a2a_intra_c(d, k) / fc,\n'
        '                                    [experts_by_dev[d][i]]))')
    src = src.replace(
        'combines.append(sim.add(f"A2A-Cx{i}", link(node),\n'
        '                                    tc.a2a_inter(node, k) / fc, deps))',
        'combines.append(sim.add(f"A2A-Cx{i}", link(node),\n'
        '                                    tc.a2a_inter_c(node, k) / fc, deps))')
    ns = {}
    exec(src, ns)
    return ns


NS = _patch_builders_for_combine()
build_pair_schedule_topo_c = NS['build_pair_schedule_topo']


def choose_expert_slot_topo_c(tc, kind, strat):
    best = (0, float('inf'))
    for slot in range(4):
        t = build_pair_schedule_topo_c(tc, kind, strat, slot).makespan()
        if t < best[1]:
            best = (slot, t)
    return best


# topologies with the planned node_intra field
def topo_intra_links(topo, node_intra=None):
    n_nodes = topo.n_devices // topo.devices_per_node
    return node_intra if node_intra else [topo.intra] * n_nodes


def topo_from_topology_pn(base, topo, tokens_per_device, token_bytes, cf,
                          node_intra=None):
    bpp = int((float(tokens_per_device) * cf / float(topo.n_devices)) * float(token_bytes))
    m = uniform_a2a_bytes(topo.n_devices, bpp)
    links = topo_intra_links(topo, node_intra)
    intra, inter = a2a_decompose_pn(m, topo.n_devices, topo.devices_per_node,
                                    links, topo.inter)
    flat = a2a_time_pn(m, topo.n_devices, topo.devices_per_node, links, topo.inter)
    per_device = []
    for d in range(topo.n_devices):
        s = topo.device_compute_scale(d)
        per_device.append(BlockCosts(base.attn / s, base.mlp / s, base.se / s,
                                     base.gate / s, base.encode / s,
                                     base.decode / s, base.expert_k1 / s, flat))
    tc = TopoCosts(per_device, intra, inter, topo.devices_per_node)
    tc.a2a_intra_c_k1 = []
    tc.a2a_inter_c_k1 = []
    return tc


def transpose(m, n):
    out = [0] * (n * n)
    for s in range(n):
        for d in range(n):
            out[d * n + s] = m[s * n + d]
    return out


def topo_from_routed(base, topo, disp_bytes, k_norm, node_intra=None):
    n = topo.n_devices
    links = topo_intra_links(topo, node_intra)
    comb_bytes = transpose(disp_bytes, n)
    di, dx = a2a_decompose_pn(disp_bytes, n, topo.devices_per_node, links, topo.inter)
    ci, cx = a2a_decompose_pn(comb_bytes, n, topo.devices_per_node, links, topo.inter)
    kf = float(k_norm)
    flat = max(a2a_time_pn(disp_bytes, n, topo.devices_per_node, links, topo.inter),
               a2a_time_pn(comb_bytes, n, topo.devices_per_node, links, topo.inter)) / kf
    di = [x / kf for x in di]
    dx = [x / kf for x in dx]
    ci = [x / kf for x in ci]
    cx = [x / kf for x in cx]
    per_device = []
    for d in range(n):
        s = topo.device_compute_scale(d)
        per_device.append(BlockCosts(base.attn / s, base.mlp / s, base.se / s,
                                     base.gate / s, base.encode / s,
                                     base.decode / s, base.expert_k1 / s, flat))
    tc = TopoCosts(per_device, di, dx, topo.devices_per_node)
    tc.a2a_intra_c_k1 = ci
    tc.a2a_inter_c_k1 = cx
    return tc


# --------------------------------------------------- routing + placement

class RoutingTable:
    def __init__(self, indices, weights, n_tokens, k, n_experts, capacity):
        assert len(indices) == n_tokens * k
        self.n_tokens = n_tokens
        self.n_experts = n_experts
        self.capacity = capacity
        self.k = k
        self.routes = []  # (token, k_slot, expert, slot, weight)
        next_slot = [0] * n_experts
        self.demand = [0] * n_experts
        self.dropped = 0
        for t in range(n_tokens):
            for kk in range(k):
                e = indices[t * k + kk]
                assert 0 <= e < n_experts
                self.demand[e] += 1
                if next_slot[e] < capacity:
                    self.routes.append((t, kk, e, next_slot[e], weights[t * k + kk]))
                    next_slot[e] += 1
                else:
                    self.dropped += 1
        self.load = next_slot

    def a2a_bytes_placed(self, placement, token_bytes):
        n_devices = placement.n_devices
        tokens_per_device = -(-self.n_tokens // n_devices)
        mat = [0] * (n_devices * n_devices)
        for (t, kk, e, slot, w) in self.routes:
            src = min(t // tokens_per_device, n_devices - 1)
            dst = placement.device_of(e)
            mat[src * n_devices + dst] += token_bytes
        return mat


class Placement:
    def __init__(self, n_experts, n_devices, mapping):
        self.n_experts = n_experts
        self.n_devices = n_devices
        self.map = mapping

    @staticmethod
    def block(n_experts, n_devices):
        assert n_experts % n_devices == 0
        per = n_experts // n_devices
        return Placement(n_experts, n_devices, [e // per for e in range(n_experts)])

    @staticmethod
    def affinity_packed(rt, n_devices, devices_per_node):
        assert n_devices % devices_per_node == 0
        n_nodes = n_devices // devices_per_node
        assert rt.n_experts % n_nodes == 0
        tokens_per_device = -(-rt.n_tokens // n_devices)
        aff = [[0] * n_nodes for _ in range(rt.n_experts)]
        for (t, kk, e, slot, w) in rt.routes:
            src = min(t // tokens_per_device, n_devices - 1)
            aff[e][src // devices_per_node] += 1
        order = sorted(range(rt.n_experts),
                       key=lambda e: (-sum(aff[e]), e))
        cap = rt.n_experts // n_nodes
        node_load = [0] * n_nodes
        mapping = [0] * rt.n_experts
        for e in order:
            best = None
            best_aff = 0
            for node in range(n_nodes):
                if node_load[node] >= cap:
                    continue
                a = aff[e][node]
                if best is None or a > best_aff:
                    best = node
                    best_aff = a
            dev = best * devices_per_node + node_load[best] % devices_per_node
            mapping[e] = dev
            node_load[best] += 1
        return Placement(rt.n_experts, n_devices, mapping)

    @staticmethod
    def imbalance_skewed(n_experts, n_devices, pack):
        assert pack >= 1 and n_experts % pack == 0
        used = n_experts // pack
        assert 1 <= used <= n_devices
        return Placement(n_experts, n_devices,
                         [e // pack for e in range(n_experts)])

    def device_of(self, e):
        return self.map[e]


# ======================================================================
# PR 3 model: token-true chunked All-to-All with per-link intra/inter
# pipelining. Transcribes the post-PR3 Rust line-by-line:
#   cluster/interconnect.rs  -> a2a_chunk_time, a2a_decompose_pn3,
#                               a2a_time_split_pn
#   moe/router.rs            -> RoutingTable.chunk (chunk_rt)
#   coordinator/costs.rs     -> BlockCosts3, TopoCosts3 (+ ChunkSource)
#   coordinator/schedule.rs  -> build_*3 builders with ChunkPipelining
# ======================================================================

import math
from dataclasses import dataclass as _dataclass


def rust_round(x):
    """f64::round (half away from zero) for non-negative x. Computed on
    the exact fractional part — floor(x + 0.5) would round up one ulp
    below .5 (x + 0.5 is inexact there) and diverge from Rust."""
    f = math.floor(x)
    return int(f) + (1 if x - f >= 0.5 else 0)


def a2a_chunk_time(full, alpha, chunks):
    assert chunks >= 1
    if chunks == 1:
        return full
    return alpha + (full - alpha) / float(chunks)


def a2a_time_split_pn(bytes_, n_devices, devices_per_node, intra_links, inter):
    n_nodes = n_devices // devices_per_node
    node_of = lambda d: d // devices_per_node
    worst = (0.0, 0.0)
    for src in range(n_devices):
        out_bytes = 0
        msgs = 0
        for dst in range(n_devices):
            if dst == src:
                continue
            b = bytes_[src * n_devices + dst]
            if b > 0:
                out_bytes += b
                msgs += 1
        l = intra_links[node_of(src)]
        a = l.alpha * float(msgs)
        t = a + float(out_bytes) / l.beta
        if t > worst[0]:
            worst = (t, a)
    if inter is not None and n_nodes > 1:
        for node in range(n_nodes):
            cross = 0
            for src in range(n_devices):
                if node_of(src) != node:
                    continue
                for dst in range(n_devices):
                    if node_of(dst) != node:
                        cross += bytes_[src * n_devices + dst]
            if cross > 0:
                t = inter.alpha + float(cross) / inter.beta
                if t > worst[0]:
                    worst = (t, inter.alpha)
    return worst


def a2a_decompose_pn3(bytes_, n_devices, devices_per_node, intra_links, inter):
    """Returns (intra, inter, intra_alpha, inter_alpha)."""
    n_nodes = n_devices // devices_per_node
    node_of = lambda d: d // devices_per_node
    split = inter is not None and n_nodes > 1
    intra_phase = []
    intra_alpha = []
    for src in range(n_devices):
        out_bytes = 0
        msgs = 0
        for dst in range(n_devices):
            if dst == src or (split and node_of(dst) != node_of(src)):
                continue
            b = bytes_[src * n_devices + dst]
            if b > 0:
                out_bytes += b
                msgs += 1
        l = intra_links[node_of(src)]
        a = l.alpha * float(msgs)
        intra_alpha.append(a)
        intra_phase.append(a + float(out_bytes) / l.beta)
    inter_phase = []
    inter_alpha = []
    if split:
        for node in range(n_nodes):
            cross = 0
            for src in range(n_devices):
                if node_of(src) != node:
                    continue
                for dst in range(n_devices):
                    if node_of(dst) != node:
                        cross += bytes_[src * n_devices + dst]
            if cross > 0:
                inter_alpha.append(inter.alpha)
                inter_phase.append(inter.alpha + float(cross) / inter.beta)
            else:
                inter_alpha.append(0.0)
                inter_phase.append(0.0)
    return intra_phase, inter_phase, intra_alpha, inter_alpha


def uniform_bytes_per_pair3(topo, tokens_per_device, token_bytes, cf):
    return rust_round((float(tokens_per_device) * cf / float(topo.n_devices))
                      * float(token_bytes))


@_dataclass
class BlockCosts3:
    attn: float; mlp: float; se: float; gate: float
    encode: float; decode: float; expert_k1: float
    a2a_k1: float; a2a_alpha_k1: float

    def expert(self, k): return self.expert_k1 * float(k)
    def a2a(self, k): return self.a2a_k1 * float(k)
    def a2a_alpha(self, k): return self.a2a_alpha_k1 * float(k)
    def a2a_chunk(self, k, chunks):
        return a2a_chunk_time(self.a2a(k), self.a2a_alpha(k), chunks)


class ChunkSource:
    def __init__(self, rt, placement, token_bytes, intra_links, inter,
                 sources=None):
        self.rt = rt
        self.placement = placement
        self.token_bytes = token_bytes
        self.intra_links = intra_links
        self.inter = inter
        self.sources = sources  # PR8: per-token source devices, or None


def chunk_rt(rt, chunks):
    """RoutingTable::chunk — contiguous token ranges, parent token space."""
    assert chunks >= 1
    size = -(-rt.n_tokens // chunks)
    parts = []
    for i in range(chunks):
        lo = min(i * size, rt.n_tokens)
        hi = min((i + 1) * size, rt.n_tokens)
        part = RoutingTable.__new__(RoutingTable)
        part.n_tokens = rt.n_tokens
        part.n_experts = rt.n_experts
        part.capacity = rt.capacity
        part.k = rt.k
        part.routes = [r for r in rt.routes if lo <= r[0] < hi]
        load = [0] * rt.n_experts
        for r in part.routes:
            load[r[2]] += 1
        part.demand = load[:]
        part.load = load
        part.dropped = (hi - lo) * rt.k - len(part.routes)
        parts.append(part)
    return parts


class TopoCosts3:
    def __init__(self, per_device, a2a_intra_k1, a2a_inter_k1,
                 devices_per_node, intra_c=None, inter_c=None,
                 intra_a=None, inter_a=None, intra_ca=None, inter_ca=None,
                 chunk_source=None):
        self.per_device = per_device
        self.a2a_intra_k1 = a2a_intra_k1
        self.a2a_inter_k1 = a2a_inter_k1
        self.a2a_intra_combine_k1 = intra_c or []
        self.a2a_inter_combine_k1 = inter_c or []
        self.a2a_intra_alpha_k1 = intra_a or []
        self.a2a_inter_alpha_k1 = inter_a or []
        self.a2a_intra_combine_alpha_k1 = intra_ca or []
        self.a2a_inter_combine_alpha_k1 = inter_ca or []
        self.chunk_source = chunk_source
        self.devices_per_node = devices_per_node

    def n_devices(self): return len(self.per_device)

    def node_of(self, d): return d // self.devices_per_node

    def devices_of(self, node):
        lo = node * self.devices_per_node
        return range(lo, min(lo + self.devices_per_node, self.n_devices()))

    def a2a_intra(self, d, k): return self.a2a_intra_k1[d] * float(k)
    def a2a_inter(self, n, k): return self.a2a_inter_k1[n] * float(k)

    def a2a_intra_combine(self, d, k):
        if not self.a2a_intra_combine_k1:
            return self.a2a_intra(d, k)
        return self.a2a_intra_combine_k1[d] * float(k)

    def a2a_inter_combine(self, n, k):
        if not self.a2a_inter_combine_k1:
            return self.a2a_inter(n, k)
        return self.a2a_inter_combine_k1[n] * float(k)

    def a2a_intra_alpha(self, d, k):
        if not self.a2a_intra_alpha_k1:
            return 0.0
        return self.a2a_intra_alpha_k1[d] * float(k)

    def a2a_inter_alpha(self, n, k):
        if not self.a2a_inter_alpha_k1:
            return 0.0
        return self.a2a_inter_alpha_k1[n] * float(k)

    def a2a_intra_combine_alpha(self, d, k):
        if not self.a2a_intra_combine_alpha_k1:
            return self.a2a_intra_alpha(d, k)
        return self.a2a_intra_combine_alpha_k1[d] * float(k)

    def a2a_inter_combine_alpha(self, n, k):
        if not self.a2a_inter_combine_alpha_k1:
            return self.a2a_inter_alpha(n, k)
        return self.a2a_inter_combine_alpha_k1[n] * float(k)

    def chunk_phases(self, k, chunks):
        assert chunks >= 1
        n = self.n_devices()
        n_links = len(self.a2a_inter_k1)
        if self.chunk_source is not None:
            src = self.chunk_source
            kf = float(max(src.rt.k, 1))
            scale = float(k) / kf
            di, dx, ci, cx = [], [], [], []
            for part in chunk_rt(src.rt, chunks):
                if src.sources is None:
                    disp = part.a2a_bytes_placed(src.placement,
                                                 src.token_bytes)
                else:
                    disp = a2a_bytes_from_sources8(part, src.sources,
                                                   src.placement,
                                                   src.token_bytes)
                comb = transpose(disp, n)
                pdi, pdx, _, _ = a2a_decompose_pn3(
                    disp, n, self.devices_per_node, src.intra_links, src.inter)
                pci, pcx, _, _ = a2a_decompose_pn3(
                    comb, n, self.devices_per_node, src.intra_links, src.inter)
                di.append([t * scale for t in pdi])
                dx.append([t * scale for t in pdx])
                ci.append([t * scale for t in pci])
                cx.append([t * scale for t in pcx])
            return di, dx, ci, cx
        di_row = [a2a_chunk_time(self.a2a_intra(d, k),
                                 self.a2a_intra_alpha(d, k), chunks)
                  for d in range(n)]
        dx_row = [a2a_chunk_time(self.a2a_inter(nd, k),
                                 self.a2a_inter_alpha(nd, k), chunks)
                  for nd in range(n_links)]
        ci_row = [a2a_chunk_time(self.a2a_intra_combine(d, k),
                                 self.a2a_intra_combine_alpha(d, k), chunks)
                  for d in range(n)]
        cx_row = [a2a_chunk_time(self.a2a_inter_combine(nd, k),
                                 self.a2a_inter_combine_alpha(nd, k), chunks)
                  for nd in range(n_links)]
        return ([di_row[:] for _ in range(chunks)],
                [dx_row[:] for _ in range(chunks)],
                [ci_row[:] for _ in range(chunks)],
                [cx_row[:] for _ in range(chunks)])


def topo_from_block3(c):
    return TopoCosts3([replace(c)], [c.a2a_k1], [], 1,
                      intra_a=[c.a2a_alpha_k1])


def block_from_topology3(base, topo, tokens_per_device, token_bytes, cf,
                         node_intra=None):
    s = topo.compute_scale
    if topo.device_scales:
        s = min(topo.device_scales)
    bpp = uniform_bytes_per_pair3(topo, tokens_per_device, token_bytes, cf)
    m = uniform_a2a_bytes(topo.n_devices, bpp)
    links = topo_intra_links(topo, node_intra)
    a2a_k1, a2a_alpha_k1 = a2a_time_split_pn(
        m, topo.n_devices, topo.devices_per_node, links, topo.inter)
    return BlockCosts3(base.attn / s, base.mlp / s, base.se / s,
                       base.gate / s, base.encode / s, base.decode / s,
                       base.expert_k1 / s, a2a_k1, a2a_alpha_k1)


def topo_from_topology3(base, topo, tokens_per_device, token_bytes, cf,
                        node_intra=None):
    bpp = uniform_bytes_per_pair3(topo, tokens_per_device, token_bytes, cf)
    m = uniform_a2a_bytes(topo.n_devices, bpp)
    links = topo_intra_links(topo, node_intra)
    intra, inter, intra_a, inter_a = a2a_decompose_pn3(
        m, topo.n_devices, topo.devices_per_node, links, topo.inter)
    flat, flat_a = a2a_time_split_pn(m, topo.n_devices, topo.devices_per_node,
                                     links, topo.inter)
    per_device = []
    for d in range(topo.n_devices):
        s = topo.device_compute_scale(d)
        per_device.append(BlockCosts3(base.attn / s, base.mlp / s, base.se / s,
                                      base.gate / s, base.encode / s,
                                      base.decode / s, base.expert_k1 / s,
                                      flat, flat_a))
    return TopoCosts3(per_device, intra, inter, topo.devices_per_node,
                      intra_a=intra_a, inter_a=inter_a)


def topo_from_routing3(base, topo, rt, placement, token_bytes,
                       node_intra=None):
    n = topo.n_devices
    links = topo_intra_links(topo, node_intra)
    disp = rt.a2a_bytes_placed(placement, token_bytes)
    comb = transpose(disp, n)
    pdi, pdx, pdia, pdxa = a2a_decompose_pn3(
        disp, n, topo.devices_per_node, links, topo.inter)
    pci, pcx, pcia, pcxa = a2a_decompose_pn3(
        comb, n, topo.devices_per_node, links, topo.inter)
    kf = float(max(rt.k, 1))
    scale = lambda v: [x / kf for x in v]
    td, ad = a2a_time_split_pn(disp, n, topo.devices_per_node, links, topo.inter)
    tcm, acm = a2a_time_split_pn(comb, n, topo.devices_per_node, links, topo.inter)
    if tcm > td:
        flat, flat_a = tcm / kf, acm / kf
    else:
        flat, flat_a = td / kf, ad / kf
    per_device = []
    for d in range(n):
        s = topo.device_compute_scale(d)
        per_device.append(BlockCosts3(base.attn / s, base.mlp / s, base.se / s,
                                      base.gate / s, base.encode / s,
                                      base.decode / s, base.expert_k1 / s,
                                      flat, flat_a))
    return TopoCosts3(per_device, scale(pdi), scale(pdx),
                      topo.devices_per_node,
                      intra_c=scale(pci), inter_c=scale(pcx),
                      intra_a=scale(pdia), inter_a=scale(pdxa),
                      intra_ca=scale(pcia), inter_ca=scale(pcxa),
                      chunk_source=ChunkSource(rt, placement, token_bytes,
                                               links, topo.inter))


# --- schedule.rs (post-PR3) -------------------------------------------

STAGED = 'staged'
PHASE_CHAINED = 'chained'


def build_sequential3(c, kind, k):
    return build_sequential(c, kind, k)


def build_pipelined3(c, kind, k, chunks):
    sim = Sim()
    attn_l = sim.add("Attn(l)", comp(DEV), c.attn, [])
    mlp_l = sim.add("MLP(l)", comp(DEV), c.mlp, [attn_l])
    attn_m = sim.add("Attn(l+1)", comp(DEV), c.attn, [mlp_l])
    gate = sim.add("Gate", comp(DEV), c.gate, [attn_m])
    enc = sim.add("Encode", comp(DEV), c.encode, [gate])
    fc = float(chunks)
    combines = []
    prev_disp = None
    for i in range(chunks):
        dd = [enc, prev_disp] if prev_disp is not None else [enc]
        disp = sim.add(f"A2A-D{i}", comm(DEV), c.a2a_chunk(k, chunks), dd)
        prev_disp = disp
        expert = sim.add(f"Expert{i}", comp(DEV), c.expert(k) / fc, [disp])
        comb = sim.add(f"A2A-C{i}", comm(DEV), c.a2a_chunk(k, chunks), [expert])
        combines.append(comb)
    decode_deps = combines[:]
    if has_shared_expert(kind):
        se = sim.add("SE", comp(DEV), c.se, [attn_m])
        decode_deps.append(se)
    sim.add("Decode", comp(DEV), c.decode, decode_deps)
    return sim


def build_overlap3(c, kind, k, slot, chunks):
    assert slot <= 3 and chunks >= 1
    sim = Sim()
    attn_l = sim.add("Attn(l)", comp(DEV), c.attn, [])
    gate = sim.add("Gate", comp(DEV), c.gate, [attn_l])
    enc = sim.add("Encode", comp(DEV), c.encode, [gate])
    fc = float(chunks)
    dispatches = []
    prev = None
    for i in range(chunks):
        deps = [enc, prev] if prev is not None else [enc]
        d = sim.add(f"A2A-D{i}", comm(DEV), c.a2a_chunk(k, chunks), deps)
        dispatches.append(d)
        prev = d
    experts = []
    last_backbone = attn_l
    window = [("MLP(l)", c.mlp), ("Attn(l+1)", c.attn), ("SE(l+1)", c.se)]
    def place_experts(after):
        tail = after
        for i, d in enumerate(dispatches):
            e = sim.add(f"Expert{i}", comp(DEV), c.expert(k) / fc, [d, tail])
            experts.append(e)
            tail = e
        return tail
    if slot == 0:
        last_backbone = place_experts(last_backbone)
    for i, (label, dur) in enumerate(window):
        last_backbone = sim.add(label, comp(DEV), dur, [last_backbone])
        if slot == i + 1:
            last_backbone = place_experts(last_backbone)
    combines = []
    for i, e in enumerate(experts):
        combines.append(sim.add(f"A2A-C{i}", comm(DEV),
                                c.a2a_chunk(k, chunks), [e]))
    deps = combines[:]
    deps.append(last_backbone)
    sim.add("Decode", comp(DEV), c.decode, deps)
    return sim


def build_pair_schedule3(c, kind, strat, slot):
    k = routed_k(kind)
    name = strat[0]
    if name == "seq":
        return build_sequential3(c, kind, k)
    if name == "pipe":
        return build_pipelined3(c, kind, k, strat[1])
    if name == "overlap":
        return build_overlap3(c, kind, k, slot, 1)
    if name == "overlap-pipe":
        return build_overlap3(c, kind, k, slot, strat[1])
    raise ValueError(name)


def add_dispatch_chunk3(sim, tc, k, i, ca, enc, prev_d, prev_x, pipelining):
    n = tc.n_devices()
    n_links = len(tc.a2a_inter_k1)
    disp_i = []
    for d in range(n):
        deps = [enc[d]]
        if prev_d[d] is not None:
            deps.append(prev_d[d])
        if pipelining == PHASE_CHAINED and n_links > 0:
            if prev_x[tc.node_of(d)] is not None:
                deps.append(prev_x[tc.node_of(d)])
        dur = ca[0][i][d] if ca is not None else tc.a2a_intra(d, k)
        t = sim.add(f"A2A-D{i}", comm(d), dur, deps)
        prev_d[d] = t
        disp_i.append(t)
    for node in range(n_links):
        if ca is not None:
            deps = [disp_i[d] for d in tc.devices_of(node)]
        else:
            deps = [enc[d] for d in tc.devices_of(node)]
        if prev_x[node] is not None:
            deps.append(prev_x[node])
        dur = ca[1][i][node] if ca is not None else tc.a2a_inter(node, k)
        t = sim.add(f"A2A-Dx{i}", link(node), dur, deps)
        prev_x[node] = t
        disp_i.append(t)
    return disp_i


def add_combine_chunk3(sim, tc, k, i, ca, experts_i, prev_c, combines,
                       pipelining):
    n = tc.n_devices()
    n_links = len(tc.a2a_inter_k1)
    if ca is not None:
        comb_x_i = []
        for node in range(n_links):
            deps = [experts_i[d] for d in tc.devices_of(node)]
            if pipelining == PHASE_CHAINED:
                for d in tc.devices_of(node):
                    if prev_c[d] is not None:
                        deps.append(prev_c[d])
            t = sim.add(f"A2A-Cx{i}", link(node), ca[3][i][node], deps)
            comb_x_i.append(t)
            combines.append(t)
        for d in range(n):
            deps = [experts_i[d]]
            if n_links > 0:
                deps.append(comb_x_i[tc.node_of(d)])
            t = sim.add(f"A2A-C{i}", comm(d), ca[2][i][d], deps)
            prev_c[d] = t
            combines.append(t)
    else:
        for d in range(n):
            t = sim.add(f"A2A-C{i}", comm(d), tc.a2a_intra_combine(d, k),
                        [experts_i[d]])
            prev_c[d] = t
            combines.append(t)
        for node in range(n_links):
            deps = [experts_i[d] for d in tc.devices_of(node)]
            combines.append(sim.add(f"A2A-Cx{i}", link(node),
                                    tc.a2a_inter_combine(node, k), deps))


def build_sequential_topo3(tc, kind, k):
    n = tc.n_devices()
    n_links = len(tc.a2a_inter_k1)
    sim = Sim()
    attn_m = []; enc = []
    for d in range(n):
        c = tc.per_device[d]
        attn_l = sim.add("Attn(l)", comp(d), c.attn, [])
        mlp_l = sim.add("MLP(l)", comp(d), c.mlp, [attn_l])
        a_m = sim.add("Attn(l+1)", comp(d), c.attn, [mlp_l])
        gate = sim.add("Gate", comp(d), c.gate, [a_m])
        e = sim.add("Encode", comp(d), c.encode, [gate])
        attn_m.append(a_m); enc.append(e)
    disp = []
    for d in range(n):
        disp.append(sim.add("A2A-D", comm(d), tc.a2a_intra(d, k), [enc[d]]))
    for node in range(n_links):
        deps = [enc[d] for d in tc.devices_of(node)]
        disp.append(sim.add("A2A-Dx", link(node), tc.a2a_inter(node, k), deps))
    experts = []
    for d in range(n):
        c = tc.per_device[d]
        experts.append(sim.add("Expert", comp(d), c.expert(k), disp))
    comb = []
    for d in range(n):
        comb.append(sim.add("A2A-C", comm(d), tc.a2a_intra_combine(d, k),
                            [experts[d]]))
    for node in range(n_links):
        deps = [experts[d] for d in tc.devices_of(node)]
        comb.append(sim.add("A2A-Cx", link(node),
                            tc.a2a_inter_combine(node, k), deps))
    for d in range(n):
        c = tc.per_device[d]
        deps = comb[:]
        if has_shared_expert(kind):
            se = sim.add("SE", comp(d), c.se, [attn_m[d]])
            deps.append(se)
        sim.add("Decode", comp(d), c.decode, deps)
    return sim


def build_pipelined_topo3(tc, kind, k, chunks, pipelining=STAGED):
    assert chunks >= 1
    n = tc.n_devices()
    n_links = len(tc.a2a_inter_k1)
    sim = Sim()
    attn_m = []; enc = []
    for d in range(n):
        c = tc.per_device[d]
        attn_l = sim.add("Attn(l)", comp(d), c.attn, [])
        mlp_l = sim.add("MLP(l)", comp(d), c.mlp, [attn_l])
        a_m = sim.add("Attn(l+1)", comp(d), c.attn, [mlp_l])
        gate = sim.add("Gate", comp(d), c.gate, [a_m])
        e = sim.add("Encode", comp(d), c.encode, [gate])
        attn_m.append(a_m); enc.append(e)
    fc = float(chunks)
    ca = tc.chunk_phases(k, chunks) if chunks > 1 else None
    prev_d = [None] * n
    prev_x = [None] * n_links
    prev_c = [None] * n
    combines = []
    for i in range(chunks):
        disp_i = add_dispatch_chunk3(sim, tc, k, i, ca, enc, prev_d, prev_x,
                                     pipelining)
        experts_i = []
        for d in range(n):
            c = tc.per_device[d]
            experts_i.append(sim.add(f"Expert{i}", comp(d),
                                     c.expert(k) / fc, disp_i))
        add_combine_chunk3(sim, tc, k, i, ca, experts_i, prev_c, combines,
                           pipelining)
    for d in range(n):
        c = tc.per_device[d]
        deps = combines[:]
        if has_shared_expert(kind):
            se = sim.add("SE", comp(d), c.se, [attn_m[d]])
            deps.append(se)
        sim.add("Decode", comp(d), c.decode, deps)
    return sim


def build_overlap_topo3(tc, kind, k, slot, chunks, pipelining=STAGED):
    assert slot <= 3 and chunks >= 1
    n = tc.n_devices()
    n_links = len(tc.a2a_inter_k1)
    sim = Sim()
    attn_l_ids = []; enc = []
    for d in range(n):
        c = tc.per_device[d]
        attn_l = sim.add("Attn(l)", comp(d), c.attn, [])
        gate = sim.add("Gate", comp(d), c.gate, [attn_l])
        e = sim.add("Encode", comp(d), c.encode, [gate])
        attn_l_ids.append(attn_l); enc.append(e)
    fc = float(chunks)
    ca = tc.chunk_phases(k, chunks) if chunks > 1 else None
    disp_chunks = []
    prev_d = [None] * n
    prev_x = [None] * n_links
    for i in range(chunks):
        disp_chunks.append(add_dispatch_chunk3(sim, tc, k, i, ca, enc,
                                               prev_d, prev_x, pipelining))
    last_backbone = [0] * n
    experts_by_dev = []
    for d in range(n):
        c = tc.per_device[d]
        dev_experts = []
        def place(after):
            tail = after
            for i, disp_i in enumerate(disp_chunks):
                deps = disp_i[:]
                deps.append(tail)
                e = sim.add(f"Expert{i}", comp(d), c.expert(k) / fc, deps)
                dev_experts.append(e)
                tail = e
            return tail
        tail = attn_l_ids[d]
        if slot == 0:
            tail = place(tail)
        window = [("MLP(l)", c.mlp), ("Attn(l+1)", c.attn), ("SE(l+1)", c.se)]
        for wi, (label, dur) in enumerate(window):
            tail = sim.add(label, comp(d), dur, [tail])
            if slot == wi + 1:
                tail = place(tail)
        last_backbone[d] = tail
        experts_by_dev.append(dev_experts)
    prev_c = [None] * n
    combines = []
    for i in range(chunks):
        experts_i = [experts_by_dev[d][i] for d in range(n)]
        add_combine_chunk3(sim, tc, k, i, ca, experts_i, prev_c, combines,
                           pipelining)
    for d in range(n):
        c = tc.per_device[d]
        deps = combines[:]
        deps.append(last_backbone[d])
        sim.add("Decode", comp(d), c.decode, deps)
    return sim


def build_pair_schedule_topo3(tc, kind, strat, slot, pipelining=STAGED):
    k = routed_k(kind)
    name = strat[0]
    if name == "seq":
        return build_sequential_topo3(tc, kind, k)
    if name == "pipe":
        return build_pipelined_topo3(tc, kind, k, strat[1], pipelining)
    if name == "overlap":
        return build_overlap_topo3(tc, kind, k, slot, 1, pipelining)
    if name == "overlap-pipe":
        return build_overlap_topo3(tc, kind, k, slot, strat[1], pipelining)
    raise ValueError(name)


def choose_expert_slot_topo3(tc, kind, strat):
    best = (0, float('inf'))
    for slot in range(4):
        t = build_pair_schedule_topo3(tc, kind, strat, slot).makespan()
        if t < best[1]:
            best = (slot, t)
    return best


# --- PR3 golden corpus generator (mirrors golden_timelines.rs) --------

def dyadic_costs3():
    return BlockCosts3(1.0, 0.75, 0.75, 0.0625, 0.0625, 0.0625, 0.5,
                       0.8125, 0.0625)


def dyadic_fleet3():
    fast = dyadic_costs3()
    slow = BlockCosts3(2.0, 1.5, 1.5, 0.125, 0.125, 0.125, 1.0,
                       0.8125, 0.0625)
    return TopoCosts3([replace(fast), fast, replace(slow), slow],
                      [0.25] * 4, [0.5] * 2, 2,
                      intra_a=[0.0625] * 4, inter_a=[0.125] * 2)


def routed_table3():
    return RoutingTable([0, 2, 0, 2, 2, 0, 0, 2, 1, 3, 3, 1, 3, 1, 3, 3],
                        [1.0] * 16, 16, 1, 4, 16)


def routed_fleet3(rt, placement):
    topo = Topology(4, 2, LinkModel(0.0625, 1024.0), LinkModel(0.125, 512.0),
                    1.0, None)
    base = ComputeCosts(1.0, 0.75, 0.75, 0.0625, 0.0625, 0.0625, 0.5)
    return topo_from_routing3(base, topo, rt, placement, 64)


def generate_corpus_lines3():
    c = dyadic_costs3()
    lines = []
    kinds = [('std', 1), ('std', 2), ('std', 3), ('shared', 1),
             ('scmoe', 1), ('scmoe', 2)]
    for kind in kinds:
        if kind[0] == 'std':
            strategies = [('seq',), ('pipe', 2), ('pipe', 4)]
        elif kind[0] == 'shared':
            strategies = [('seq',), ('pipe', 1), ('pipe', 2)]
        else:
            strategies = [('seq',), ('pipe', 2)]
        for strategy in strategies:
            slabel = 'seq' if strategy[0] == 'seq' else f'pipe{strategy[1]}'
            name = f'{kind_label(kind)}/{slabel}'
            lines.append(render_line(name, build_pair_schedule3(c, kind, strategy, 0)))
        if kind[0] == 'scmoe':
            for slot in range(4):
                s = build_pair_schedule3(c, kind, ('overlap',), slot)
                lines.append(render_line(f'{kind_label(kind)}/overlap-s{slot}', s))
            for slot in range(4):
                s = build_pair_schedule3(c, kind, ('overlap-pipe', 2), slot)
                lines.append(render_line(
                    f'{kind_label(kind)}/overlap+pipe2-s{slot}', s))
    tf = dyadic_fleet3()
    lines.append(render_line('fleet:Top2/seq',
                             build_pair_schedule_topo3(tf, ('std', 2), ('seq',), 0)))
    lines.append(render_line('fleet:Top2/pipe2',
                             build_pair_schedule_topo3(tf, ('std', 2), ('pipe', 2), 0)))
    lines.append(render_line(
        'fleet:Top2/pipe2-chained',
        build_pair_schedule_topo3(tf, ('std', 2), ('pipe', 2), 0,
                                  PHASE_CHAINED)))
    for slot in range(4):
        lines.append(render_line(
            f'fleet:ScMoE/overlap-s{slot}',
            build_pair_schedule_topo3(tf, ('scmoe', 1), ('overlap',), slot)))
    lines.append(render_line(
        'fleet:ScMoE/overlap+pipe2-s2',
        build_pair_schedule_topo3(tf, ('scmoe', 1), ('overlap-pipe', 2), 2)))
    rt = routed_table3()
    for name, p in [('block', Placement.block(4, 4)),
                    ('affinity', Placement.affinity_packed(rt, 4, 2)),
                    ('skewed', Placement.imbalance_skewed(4, 4, 2))]:
        tc = routed_fleet3(rt, p)
        lines.append(render_line(f'routed:{name}/seq',
                     build_pair_schedule_topo3(tc, ('scmoe', 1), ('seq',), 0)))
        lines.append(render_line(f'routed:{name}/overlap-s2',
                     build_pair_schedule_topo3(tc, ('scmoe', 1), ('overlap',), 2)))
        lines.append(render_line(
            f'routed:{name}/overlap+pipe2-s2',
            build_pair_schedule_topo3(tc, ('scmoe', 1), ('overlap-pipe', 2), 2)))
    return lines


def validate_corpus3():
    golden_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                               '..', '..', 'rust', 'tests', 'golden',
                               'timelines.txt')
    golden = [l for l in open(golden_path).read().splitlines()
              if l.strip() and not l.startswith('#')]
    lines = generate_corpus_lines3()
    bad = 0
    if len(golden) != len(lines):
        print(f'line-count mismatch: golden {len(golden)} vs mirror {len(lines)}')
        bad += 1
    for g, cu in zip(golden, lines):
        if g != cu:
            bad += 1
            print('- ' + g)
            print('+ ' + cu)
    print(f'golden corpus (PR3 model): {len(lines)} lines, {bad} mismatches')
    return bad == 0


def consistency_checks3():
    """Internal reductions the PR3 model must satisfy before any of its
    output is trusted as a golden value."""
    # 1. chunks=1 schedules are byte-identical to the pre-PR3 (seed)
    #    builders on the dyadic corpus costs — the α decomposition and
    #    staging must not perturb unchunked schedules.
    c_old = dyadic_costs()
    c_new = dyadic_costs3()
    for kind in [('std', 2), ('shared', 1), ('scmoe', 1), ('scmoe', 2)]:
        a = render_line('x', build_pair_schedule(c_old, kind, ('seq',), 0))
        b = render_line('x', build_pair_schedule3(c_new, kind, ('seq',), 0))
        assert a == b, ('seq drifted', kind)
        if kind[0] == 'scmoe':
            for slot in range(4):
                a = render_line('x', build_pair_schedule(
                    c_old, kind, ('overlap',), slot))
                b = render_line('x', build_pair_schedule3(
                    c_new, kind, ('overlap',), slot))
                assert a == b, ('overlap drifted', kind, slot)
    tf_old = dyadic_fleet()
    tf_new = dyadic_fleet3()
    for slot in range(4):
        a = render_line('x', build_pair_schedule_topo(tf_old, ('scmoe', 1),
                                                      ('overlap',), slot))
        b = render_line('x', build_pair_schedule_topo3(tf_new, ('scmoe', 1),
                                                       ('overlap',), slot))
        assert a == b, ('fleet overlap drifted', slot)
    # 2. zero-α chunking reduces to the seed's plain division.
    from dataclasses import replace as _rep
    c_free = _rep(c_new)
    c_free.a2a_alpha_k1 = 0.0
    a = render_line('x', build_pair_schedule(c_old, ('std', 2), ('pipe', 2), 0))
    b = render_line('x', build_pair_schedule3(c_free, ('std', 2), ('pipe', 2), 0))
    assert a == b, 'zero-α legacy chunking drifted from the seed division'
    # 3. staged is never slower than phase-chained on the dyadic fleet.
    for chunks in [2, 4]:
        st = build_pair_schedule_topo3(tf_new, ('std', 2), ('pipe', chunks),
                                       0, STAGED).makespan()
        ch = build_pair_schedule_topo3(tf_new, ('std', 2), ('pipe', chunks),
                                       0, PHASE_CHAINED).makespan()
        assert st <= ch + 1e-12, (chunks, st, ch)
    print('PR3 consistency checks: OK')


# ======================================================================
# PR 4 model: ScheduleSpec + CostModel unified builders with load-scaled,
# token-true expert compute. Transcribes the planned Rust line-by-line:
#   moe/placement.rs        -> ExpertLoad (RoutingTable::load x Placement)
#   coordinator/spec.rs     -> CostModel phase queries (PhaseDir/PhaseScope),
#                              here CostModelBlock + TopoCosts4
#   coordinator/schedule.rs -> the unified spec-driven builders (one family
#                              serving both the single-device and fleet
#                              back ends; sequential/pipelined/overlap share
#                              the prologue/dispatch/combine/decode helpers)
# ======================================================================

DISPATCH, COMBINE = 0, 1
INTRA, INTER = 0, 1


class ExpertLoad:
    """Per-device routed compute load (kept token copies)."""

    def __init__(self, per_device):
        self.per_device = per_device
        self.total = sum(per_device)

    @staticmethod
    def from_routing(rt, placement):
        per = [0] * placement.n_devices
        for e, l in enumerate(rt.load):
            per[placement.device_of(e)] += l
        return ExpertLoad(per)

    def scale(self, d):
        # load_d / mean load; exactly 1.0 for balanced loads so balanced
        # routing reduces bit-exactly to the unscaled model
        if self.total == 0:
            return 0.0
        return (float(self.per_device[d]) * float(len(self.per_device))
                / float(self.total))

    def imbalance(self):
        if self.total == 0:
            return 1.0
        mean = float(self.total) / float(len(self.per_device))
        return float(max(self.per_device)) / mean


class CostModelBlock:
    """BlockCosts3 viewed through the CostModel interface (1 device)."""

    def __init__(self, c):
        self.c = c

    def n_devices(self): return 1
    def devices_per_node(self): return 1
    def n_links(self): return 0
    def node_of(self, d): return 0
    def devices_of(self, node): return range(0, 1)
    def device(self, d): return self.c

    def phase(self, dir_, scope, idx, k):
        return self.c.a2a(k)

    def phase_alpha(self, dir_, scope, idx, k):
        return self.c.a2a_alpha(k)

    def expert_time(self, d, k):
        return self.c.expert(k)

    def chunk_phases(self, k, chunks):
        row = [a2a_chunk_time(self.c.a2a(k), self.c.a2a_alpha(k), chunks)]
        ex = [self.c.expert(k) / float(chunks)]
        return ([row[:] for _ in range(chunks)],
                [[] for _ in range(chunks)],
                [row[:] for _ in range(chunks)],
                [[] for _ in range(chunks)],
                [ex[:] for _ in range(chunks)])


class TopoCosts4(TopoCosts3):
    """TopoCosts3 + the per-device ExpertLoad and CostModel queries."""

    def __init__(self, base3, expert_load=None):
        TopoCosts3.__init__(
            self, base3.per_device, base3.a2a_intra_k1, base3.a2a_inter_k1,
            base3.devices_per_node,
            intra_c=base3.a2a_intra_combine_k1,
            inter_c=base3.a2a_inter_combine_k1,
            intra_a=base3.a2a_intra_alpha_k1,
            inter_a=base3.a2a_inter_alpha_k1,
            intra_ca=base3.a2a_intra_combine_alpha_k1,
            inter_ca=base3.a2a_inter_combine_alpha_k1,
            chunk_source=base3.chunk_source)
        self.expert_load = expert_load

    def n_links(self): return len(self.a2a_inter_k1)
    def device(self, d): return self.per_device[d]

    def phase(self, dir_, scope, idx, k):
        if dir_ == DISPATCH:
            return (self.a2a_intra(idx, k) if scope == INTRA
                    else self.a2a_inter(idx, k))
        return (self.a2a_intra_combine(idx, k) if scope == INTRA
                else self.a2a_inter_combine(idx, k))

    def phase_alpha(self, dir_, scope, idx, k):
        if dir_ == DISPATCH:
            return (self.a2a_intra_alpha(idx, k) if scope == INTRA
                    else self.a2a_inter_alpha(idx, k))
        return (self.a2a_intra_combine_alpha(idx, k) if scope == INTRA
                else self.a2a_inter_combine_alpha(idx, k))

    def expert_time(self, d, k):
        base = self.per_device[d].expert(k)
        if self.expert_load is None:
            return base
        return base * self.expert_load.scale(d)

    def chunk_phases(self, k, chunks):
        base = TopoCosts3.chunk_phases(self, k, chunks)
        n = self.n_devices()
        fc = float(chunks)
        token_true = (self.chunk_source is not None
                      and self.expert_load is not None
                      and self.expert_load.total > 0)
        if token_true:
            total = float(self.expert_load.total)
            ex = []
            for part in chunk_rt(self.chunk_source.rt, chunks):
                # per-chunk device loads via ExpertLoad, scaled against the
                # PARENT total so chunk durations partition expert_time
                pl = ExpertLoad.from_routing(part,
                                             self.chunk_source.placement)
                row = []
                for d in range(n):
                    scale = float(pl.per_device[d]) * float(n) / total
                    row.append(self.per_device[d].expert(k) * scale)
                ex.append(row)
        else:
            ex = [[self.expert_time(d, k) / fc for d in range(n)]
                  for _ in range(chunks)]
        return base + (ex,)


def topo_from_routing4(base, topo, rt, placement, token_bytes,
                       node_intra=None):
    return TopoCosts4(
        topo_from_routing3(base, topo, rt, placement, token_bytes, node_intra),
        ExpertLoad.from_routing(rt, placement))


# --- unified spec-driven builders (schedule.rs, post-PR4) -------------

def add_backbone_head4(sim, cm, shortcut):
    """Per-device backbone prologue shared by every builder. Non-shortcut
    kinds anchor the MoE stream on Attn(l+1); the shortcut (ScMoE) anchors
    it on the preceding layer's Attn(l)."""
    anchors = []
    enc = []
    for d in range(cm.n_devices()):
        c = cm.device(d)
        attn_l = sim.add("Attn(l)", comp(d), c.attn, [])
        if shortcut:
            anchor = attn_l
        else:
            mlp_l = sim.add("MLP(l)", comp(d), c.mlp, [attn_l])
            anchor = sim.add("Attn(l+1)", comp(d), c.attn, [mlp_l])
        gate = sim.add("Gate", comp(d), c.gate, [anchor])
        e = sim.add("Encode", comp(d), c.encode, [gate])
        anchors.append(anchor)
        enc.append(e)
    return anchors, enc


def add_dispatch_chunk4(sim, cm, k, i, ca, enc, prev_d, prev_x, pipelining):
    """i=None -> the unchunked collective ('A2A-D'); i=int -> chunk i."""
    n = cm.n_devices()
    n_links = cm.n_links()
    tag = '' if i is None else str(i)
    ci = 0 if i is None else i
    disp_i = []
    for d in range(n):
        deps = [enc[d]]
        if prev_d[d] is not None:
            deps.append(prev_d[d])
        if pipelining == PHASE_CHAINED and n_links > 0:
            if prev_x[cm.node_of(d)] is not None:
                deps.append(prev_x[cm.node_of(d)])
        dur = ca[0][ci][d] if ca is not None else cm.phase(DISPATCH, INTRA, d, k)
        t = sim.add(f"A2A-D{tag}", comm(d), dur, deps)
        prev_d[d] = t
        disp_i.append(t)
    for node in range(n_links):
        if ca is not None:
            deps = [disp_i[d] for d in cm.devices_of(node)]
        else:
            deps = [enc[d] for d in cm.devices_of(node)]
        if prev_x[node] is not None:
            deps.append(prev_x[node])
        dur = (ca[1][ci][node] if ca is not None
               else cm.phase(DISPATCH, INTER, node, k))
        t = sim.add(f"A2A-Dx{tag}", link(node), dur, deps)
        prev_x[node] = t
        disp_i.append(t)
    return disp_i


def add_combine_chunk4(sim, cm, k, i, ca, experts_i, prev_c, combines,
                       pipelining):
    n = cm.n_devices()
    n_links = cm.n_links()
    tag = '' if i is None else str(i)
    ci = 0 if i is None else i
    if ca is not None:
        comb_x_i = []
        for node in range(n_links):
            deps = [experts_i[d] for d in cm.devices_of(node)]
            if pipelining == PHASE_CHAINED:
                for d in cm.devices_of(node):
                    if prev_c[d] is not None:
                        deps.append(prev_c[d])
            t = sim.add(f"A2A-Cx{tag}", link(node), ca[3][ci][node], deps)
            comb_x_i.append(t)
            combines.append(t)
        for d in range(n):
            deps = [experts_i[d]]
            if n_links > 0:
                deps.append(comb_x_i[cm.node_of(d)])
            t = sim.add(f"A2A-C{tag}", comm(d), ca[2][ci][d], deps)
            prev_c[d] = t
            combines.append(t)
    else:
        for d in range(n):
            t = sim.add(f"A2A-C{tag}", comm(d), cm.phase(COMBINE, INTRA, d, k),
                        [experts_i[d]])
            prev_c[d] = t
            combines.append(t)
        for node in range(n_links):
            deps = [experts_i[d] for d in cm.devices_of(node)]
            combines.append(sim.add(f"A2A-Cx{tag}", link(node),
                                    cm.phase(COMBINE, INTER, node, k), deps))


def add_decode4(sim, cm, kind, combines, attn_m, last_backbone):
    for d in range(cm.n_devices()):
        c = cm.device(d)
        deps = combines[:]
        if last_backbone is not None:
            deps.append(last_backbone[d])
        elif has_shared_expert(kind):
            se = sim.add("SE", comp(d), c.se, [attn_m[d]])
            deps.append(se)
        sim.add("Decode", comp(d), c.decode, deps)


def build_sequential4(cm, kind, k):
    sim = Sim()
    attn_m, enc = add_backbone_head4(sim, cm, False)
    n = cm.n_devices()
    prev_d = [None] * n
    prev_x = [None] * cm.n_links()
    prev_c = [None] * n
    disp = add_dispatch_chunk4(sim, cm, k, None, None, enc, prev_d, prev_x,
                               STAGED)
    experts = [sim.add("Expert", comp(d), cm.expert_time(d, k), disp)
               for d in range(n)]
    combines = []
    add_combine_chunk4(sim, cm, k, None, None, experts, prev_c, combines,
                       STAGED)
    add_decode4(sim, cm, kind, combines, attn_m, None)
    return sim


def build_pipelined4(cm, kind, k, chunks, pipelining=STAGED):
    assert chunks >= 1
    sim = Sim()
    attn_m, enc = add_backbone_head4(sim, cm, False)
    n = cm.n_devices()
    fc = float(chunks)
    ca = cm.chunk_phases(k, chunks) if chunks > 1 else None
    prev_d = [None] * n
    prev_x = [None] * cm.n_links()
    prev_c = [None] * n
    combines = []
    for i in range(chunks):
        disp_i = add_dispatch_chunk4(sim, cm, k, i, ca, enc, prev_d, prev_x,
                                     pipelining)
        experts_i = []
        for d in range(n):
            dur = ca[4][i][d] if ca is not None else cm.expert_time(d, k) / fc
            experts_i.append(sim.add(f"Expert{i}", comp(d), dur, disp_i))
        add_combine_chunk4(sim, cm, k, i, ca, experts_i, prev_c, combines,
                           pipelining)
    add_decode4(sim, cm, kind, combines, attn_m, None)
    return sim


def build_overlap4(cm, kind, k, slot, chunks, pipelining=STAGED):
    assert slot <= 3 and chunks >= 1
    sim = Sim()
    attn_l_ids, enc = add_backbone_head4(sim, cm, True)
    n = cm.n_devices()
    fc = float(chunks)
    ca = cm.chunk_phases(k, chunks) if chunks > 1 else None
    disp_chunks = []
    prev_d = [None] * n
    prev_x = [None] * cm.n_links()
    for i in range(chunks):
        disp_chunks.append(add_dispatch_chunk4(sim, cm, k, i, ca, enc,
                                               prev_d, prev_x, pipelining))
    last_backbone = [0] * n
    experts_by_dev = []
    for d in range(n):
        c = cm.device(d)
        dev_experts = []

        def place(after):
            tail = after
            for i, disp_i in enumerate(disp_chunks):
                deps = disp_i[:]
                deps.append(tail)
                dur = (ca[4][i][d] if ca is not None
                       else cm.expert_time(d, k) / fc)
                e = sim.add(f"Expert{i}", comp(d), dur, deps)
                dev_experts.append(e)
                tail = e
            return tail

        tail = attn_l_ids[d]
        if slot == 0:
            tail = place(tail)
        window = [("MLP(l)", c.mlp), ("Attn(l+1)", c.attn), ("SE(l+1)", c.se)]
        for wi, (label, dur) in enumerate(window):
            tail = sim.add(label, comp(d), dur, [tail])
            if slot == wi + 1:
                tail = place(tail)
        last_backbone[d] = tail
        experts_by_dev.append(dev_experts)
    prev_c = [None] * n
    combines = []
    for i in range(chunks):
        experts_i = [experts_by_dev[d][i] for d in range(n)]
        add_combine_chunk4(sim, cm, k, i, ca, experts_i, prev_c, combines,
                           pipelining)
    add_decode4(sim, cm, kind, combines, None, last_backbone)
    return sim


def build_spec4(cm, kind, strat, slot=0, pipelining=STAGED):
    """ScheduleSpec::build — the one entry point."""
    k = routed_k(kind)
    name = strat[0]
    if name == 'seq':
        return build_sequential4(cm, kind, k)
    if name == 'pipe':
        return build_pipelined4(cm, kind, k, strat[1], pipelining)
    if name == 'overlap':
        return build_overlap4(cm, kind, k, slot, 1, pipelining)
    if name == 'overlap-pipe':
        return build_overlap4(cm, kind, k, slot, strat[1], pipelining)
    raise ValueError(name)


def choose_expert_slot4(cm, kind, strat, pipelining=STAGED):
    best = (0, float('inf'))
    for slot in range(4):
        t = build_spec4(cm, kind, strat, slot, pipelining).makespan()
        if t < best[1]:
            best = (slot, t)
    return best


# --- report/efficiency.rs helpers needed for expectation minting ------

def xl_compute_costs():
    return ComputeCosts(1.40e-3, 1.20e-3, 1.20e-3, 0.09e-3, 0.07e-3,
                        0.07e-3, 1.40e-3)


def node_affine_routing(n_devices, devices_per_node, n_experts,
                        tokens_per_device, k, seed):
    n_nodes = n_devices // devices_per_node
    group = n_experts // n_nodes
    n_tokens = n_devices * tokens_per_device
    rng = Rng(seed)
    indices = []
    weights = [1.0] * (n_tokens * k)
    for t in range(n_tokens):
        node = (t // tokens_per_device) // devices_per_node
        first = rng.below(group)
        indices.append(node + n_nodes * first)
        rest = [(first + o) % group for o in range(1, group)]
        for _ in range(1, k):
            idx = rest.pop(rng.below(len(rest)))
            indices.append(node + n_nodes * idx)
    return RoutingTable(indices, weights, n_tokens, k, n_experts, n_tokens)


def consistency_checks4():
    """Reductions the PR4 model must satisfy before its output is trusted:
    the unified spec builders must reproduce the PR3 builders bit-exactly
    wherever no load information exists, and balanced loads must be the
    identity."""
    c = dyadic_costs3()
    cm = CostModelBlock(c)
    kinds = [('std', 1), ('std', 2), ('std', 3), ('shared', 1),
             ('scmoe', 1), ('scmoe', 2)]
    # 1. single-device back end == legacy single-device builders
    for kind in kinds:
        for strat in [('seq',), ('pipe', 1), ('pipe', 2), ('pipe', 4)]:
            a = render_line('x', build_pair_schedule3(c, kind, strat, 0))
            b = render_line('x', build_spec4(cm, kind, strat, 0))
            assert a == b, ('single-device spec drifted', kind, strat)
        for slot in range(4):
            for strat in [('overlap',), ('overlap-pipe', 2)]:
                a = render_line('x', build_pair_schedule3(c, kind if kind[0] == 'scmoe' else ('scmoe', 1), strat, slot))
                b = render_line('x', build_spec4(CostModelBlock(c), kind if kind[0] == 'scmoe' else ('scmoe', 1), strat, slot))
                assert a == b, ('single-device overlap drifted', kind, strat, slot)
    # 2. fleet back end without loads == PR3 topo builders
    tf3 = dyadic_fleet3()
    tf4 = TopoCosts4(tf3)
    fleet_cases = [(('std', 2), ('seq',), 0, STAGED),
                   (('std', 2), ('pipe', 2), 0, STAGED),
                   (('std', 2), ('pipe', 2), 0, PHASE_CHAINED),
                   (('std', 2), ('pipe', 4), 0, STAGED),
                   (('scmoe', 1), ('overlap-pipe', 2), 2, STAGED),
                   (('scmoe', 1), ('overlap-pipe', 2), 2, PHASE_CHAINED)]
    for slot in range(4):
        fleet_cases.append((('scmoe', 1), ('overlap',), slot, STAGED))
    for kind, strat, slot, pipe in fleet_cases:
        a = render_line('x', build_pair_schedule_topo3(tf3, kind, strat, slot, pipe))
        b = render_line('x', build_spec4(tf4, kind, strat, slot, pipe))
        assert a == b, ('fleet spec drifted', kind, strat, slot, pipe)
    # 3. balanced routed loads are the identity: every expert equally hot
    idx = [0, 1, 2, 3] * 4
    rt_bal = RoutingTable(idx, [1.0] * 16, 16, 1, 4, 16)
    for pname, p in [('block', Placement.block(4, 4))]:
        tc3 = routed_fleet3_with(rt_bal, p)
        tc4 = topo_from_routing4(ComputeCosts(1.0, 0.75, 0.75, 0.0625, 0.0625,
                                              0.0625, 0.5),
                                 Topology(4, 2, LinkModel(0.0625, 1024.0),
                                          LinkModel(0.125, 512.0), 1.0, None),
                                 rt_bal, p, 64)
        assert tc4.expert_load.scale(0) == 1.0
        for kind, strat, slot in [(('scmoe', 1), ('seq',), 0),
                                  (('scmoe', 1), ('overlap',), 2),
                                  (('scmoe', 1), ('overlap-pipe', 2), 2),
                                  (('scmoe', 1), ('pipe', 2), 0)]:
            a = render_line('x', build_pair_schedule_topo3(tc3, kind, strat, slot))
            b = render_line('x', build_spec4(tc4, kind, strat, slot))
            assert a == b, ('balanced routed drifted', pname, kind, strat)
    # 4. per-chunk expert loads partition the device loads (integers)
    rt = routed_table3()
    for pname, p in [('block', Placement.block(4, 4)),
                     ('skewed', Placement.imbalance_skewed(4, 4, 2))]:
        tc4 = topo_from_routing4(ComputeCosts(1.0, 0.75, 0.75, 0.0625, 0.0625,
                                              0.0625, 0.5),
                                 Topology(4, 2, LinkModel(0.0625, 1024.0),
                                          LinkModel(0.125, 512.0), 1.0, None),
                                 rt, p, 64)
        for chunks in [2, 3, 4]:
            ca = tc4.chunk_phases(1, chunks)
            for d in range(4):
                total = sum(ca[4][i][d] for i in range(chunks))
                assert abs(total - tc4.expert_time(d, 1)) < 1e-12, (pname, d)
    # 5. a skewed placement strictly stretches the hot device's expert span
    skew = topo_from_routing4(ComputeCosts(1.0, 0.75, 0.75, 0.0625, 0.0625,
                                           0.0625, 0.5),
                              Topology(4, 2, LinkModel(0.0625, 1024.0),
                                       LinkModel(0.125, 512.0), 1.0, None),
                              rt, Placement.imbalance_skewed(4, 4, 2), 64)
    naive = TopoCosts4(TopoCosts3(skew.per_device, skew.a2a_intra_k1,
                                  skew.a2a_inter_k1, skew.devices_per_node,
                                  intra_c=skew.a2a_intra_combine_k1,
                                  inter_c=skew.a2a_inter_combine_k1,
                                  intra_a=skew.a2a_intra_alpha_k1,
                                  inter_a=skew.a2a_inter_alpha_k1,
                                  intra_ca=skew.a2a_intra_combine_alpha_k1,
                                  inter_ca=skew.a2a_inter_combine_alpha_k1,
                                  chunk_source=skew.chunk_source))
    assert skew.expert_time(0, 1) > naive.expert_time(0, 1)
    m_true = build_spec4(skew, ('scmoe', 1), ('seq',), 0).makespan()
    m_naive = build_spec4(naive, ('scmoe', 1), ('seq',), 0).makespan()
    assert m_true > m_naive, (m_true, m_naive)
    print('PR4 consistency checks: OK')


def routed_fleet3_with(rt, placement):
    topo = Topology(4, 2, LinkModel(0.0625, 1024.0), LinkModel(0.125, 512.0),
                    1.0, None)
    base = ComputeCosts(1.0, 0.75, 0.75, 0.0625, 0.0625, 0.0625, 0.5)
    return topo_from_routing3(base, topo, rt, placement, 64)


def routed_fleet4(rt, placement):
    topo = Topology(4, 2, LinkModel(0.0625, 1024.0), LinkModel(0.125, 512.0),
                    1.0, None)
    base = ComputeCosts(1.0, 0.75, 0.75, 0.0625, 0.0625, 0.0625, 0.5)
    return topo_from_routing4(base, topo, rt, placement, 64)


def generate_corpus_lines4():
    """The post-PR4 golden corpus: identical to the PR3 corpus wherever no
    load information exists (pinned by consistency_checks4), load-scaled
    expert spans on the routed entries, plus new routed pipe2 entries whose
    per-chunk expert durations are token-true."""
    c = dyadic_costs3()
    cm = CostModelBlock(c)
    lines = []
    kinds = [('std', 1), ('std', 2), ('std', 3), ('shared', 1),
             ('scmoe', 1), ('scmoe', 2)]
    for kind in kinds:
        if kind[0] == 'std':
            strategies = [('seq',), ('pipe', 2), ('pipe', 4)]
        elif kind[0] == 'shared':
            strategies = [('seq',), ('pipe', 1), ('pipe', 2)]
        else:
            strategies = [('seq',), ('pipe', 2)]
        for strategy in strategies:
            slabel = 'seq' if strategy[0] == 'seq' else f'pipe{strategy[1]}'
            name = f'{kind_label(kind)}/{slabel}'
            lines.append(render_line(name, build_spec4(cm, kind, strategy, 0)))
        if kind[0] == 'scmoe':
            for slot in range(4):
                s = build_spec4(cm, kind, ('overlap',), slot)
                lines.append(render_line(f'{kind_label(kind)}/overlap-s{slot}', s))
            for slot in range(4):
                s = build_spec4(cm, kind, ('overlap-pipe', 2), slot)
                lines.append(render_line(
                    f'{kind_label(kind)}/overlap+pipe2-s{slot}', s))
    tf = TopoCosts4(dyadic_fleet3())
    lines.append(render_line('fleet:Top2/seq',
                             build_spec4(tf, ('std', 2), ('seq',), 0)))
    lines.append(render_line('fleet:Top2/pipe2',
                             build_spec4(tf, ('std', 2), ('pipe', 2), 0)))
    lines.append(render_line(
        'fleet:Top2/pipe2-chained',
        build_spec4(tf, ('std', 2), ('pipe', 2), 0, PHASE_CHAINED)))
    for slot in range(4):
        lines.append(render_line(
            f'fleet:ScMoE/overlap-s{slot}',
            build_spec4(tf, ('scmoe', 1), ('overlap',), slot)))
    lines.append(render_line(
        'fleet:ScMoE/overlap+pipe2-s2',
        build_spec4(tf, ('scmoe', 1), ('overlap-pipe', 2), 2)))
    rt = routed_table3()
    for name, p in [('block', Placement.block(4, 4)),
                    ('affinity', Placement.affinity_packed(rt, 4, 2)),
                    ('skewed', Placement.imbalance_skewed(4, 4, 2))]:
        tc = routed_fleet4(rt, p)
        lines.append(render_line(f'routed:{name}/seq',
                     build_spec4(tc, ('scmoe', 1), ('seq',), 0)))
        lines.append(render_line(f'routed:{name}/overlap-s2',
                     build_spec4(tc, ('scmoe', 1), ('overlap',), 2)))
        lines.append(render_line(
            f'routed:{name}/overlap+pipe2-s2',
            build_spec4(tc, ('scmoe', 1), ('overlap-pipe', 2), 2)))
        lines.append(render_line(
            f'routed:{name}/pipe2',
            build_spec4(tc, ('scmoe', 1), ('pipe', 2), 0)))
    return lines


def validate_corpus4():
    golden_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                               '..', '..', 'rust', 'tests', 'golden',
                               'timelines.txt')
    golden = [l for l in open(golden_path).read().splitlines()
              if l.strip() and not l.startswith('#')]
    lines = generate_corpus_lines4()
    bad = 0
    if len(golden) != len(lines):
        print(f'line-count mismatch: golden {len(golden)} vs mirror {len(lines)}')
        bad += 1
    for g, cu in zip(golden, lines):
        if g != cu:
            bad += 1
            print('- ' + g)
            print('+ ' + cu)
    print(f'golden corpus (PR4 model): {len(lines)} lines, {bad} mismatches')
    return bad == 0


CORPUS_HEADER3 = """# Golden operator timelines for every MoEKind x Strategy combination.
#
# Format: <kind>/<strategy>[-s<slot>] | makespan <secs> | <spans...>
#   span token = <label>@<resource>@<start>, resources c<dev>=compute,
#   m<dev>=comm, l<node>=link; spans sorted by (start, task id).
# Costs are dyadic rationals (exact in binary floating point), so every
# value formats exactly at 6 decimals and any schedule change — reordered
# spans, shifted starts, changed makespan — diffs cleanly.
#
# Chunked entries (pipe2/pipe4/overlap+pipe2) price every chunk at
# alpha + bytes/chunks/beta (the launch latency is NOT amortized across
# chunks) and, on fleets, stage each chunk's uplink behind that node's
# intra tasks; the `-chained` fleet entry pins the PhaseChained A/B
# baseline. Routed overlap+pipe2 entries use token-true per-chunk byte
# matrices (RoutingTable::chunk), so the skewed placement's chunks carry
# genuinely different traffic.
#
# Routed entries carry load-scaled expert compute (ExpertLoad =
# RoutingTable::load x Placement): a device's Expert span is stretched by
# load_d / mean_load, so the imbalanced dyadic routing (per-expert loads
# 4/3/4/5) yields visibly unequal Expert spans per placement, and the
# routed pipe2 entries additionally split each device's expert time by
# its per-chunk token share (token-true chunked compute). Balanced
# routing reduces to scale 1.0 exactly, leaving every other entry
# byte-identical to the pre-load-model corpus.
#
# Regenerated only deliberately (tools/des_mirror/mirror2.py --emit):
# these snapshots pin Fig. 6 span order so schedule refactors cannot
# silently reorder the paper's timelines."""


def emit_corpus4(path):
    keep = CORPUS_HEADER3.splitlines()
    lines = generate_corpus_lines4()
    routed_at = next(i for i, l in enumerate(lines) if l.startswith('routed:'))
    routed_comment = [
        '# Routed-placement scenarios (dyadic 4-device/2-node fleet; see',
        '# routed_table/routed_fleet in golden_timelines.rs).',
    ]
    body = lines[:routed_at] + routed_comment + lines[routed_at:]
    with open(path, 'w') as f:
        f.write('\n'.join(keep) + '\n' + '\n'.join(body) + '\n')
    print(f'emitted {len(lines)} corpus lines to {path}')


# ======================================================================
# PR 5 model: measured-affinity live re-placement with migration-aware
# multi-step timelines. Transcribes the planned Rust line-by-line:
#   moe/estimator.rs        -> AffinityEstimator (EWMA/counting over a
#                              RoutingTable stream)
#   moe/placement.rs        -> Placement::affinity_packed_measured (the
#                              greedy packer over a measured f64 matrix;
#                              affinity_packed becomes a one-shot wrapper)
#   coordinator/replace.rs  -> MigrationPlan (expert->device deltas with
#                              per-expert byte costs, priced as H2D DES
#                              tasks), ReplacePolicy, run_replace_timeline
#   report/efficiency.rs    -> drifting_node_affine_routing (seeded drift
#                              + regime-shift scenario generator)
# ======================================================================


def h2d(d):
    return ("h2d", d)


def transfer_time(link, bytes_):
    """LinkModel::transfer_time — zero bytes send no message."""
    if bytes_ == 0:
        return 0.0
    return link.alpha + float(bytes_) / link.beta


def affinity_packed_measured(aff, n_experts, n_devices, devices_per_node):
    """Placement::affinity_packed_measured — the ExFlow-style greedy
    packer over a row-major [n_experts, n_nodes] measured affinity
    matrix (f64). Integer-valued matrices reproduce the one-shot
    Placement.affinity_packed bit-exactly (checked in
    consistency_checks5)."""
    assert devices_per_node > 0 and n_devices % devices_per_node == 0
    n_nodes = n_devices // devices_per_node
    assert len(aff) == n_experts * n_nodes
    assert n_experts % n_nodes == 0
    total = [sum(aff[e * n_nodes:(e + 1) * n_nodes])
             for e in range(n_experts)]
    order = sorted(range(n_experts), key=lambda e: (-total[e], e))
    cap = n_experts // n_nodes
    node_load = [0] * n_nodes
    mapping = [0] * n_experts
    for e in order:
        best = None
        best_aff = 0.0
        for node in range(n_nodes):
            if node_load[node] >= cap:
                continue
            a = aff[e * n_nodes + node]
            if best is None or a > best_aff:
                best = node
                best_aff = a
        mapping[e] = best * devices_per_node + node_load[best] % devices_per_node
        node_load[best] += 1
    return Placement(n_experts, n_devices, mapping)


class AffinityEstimator:
    """moe::AffinityEstimator — discounted (expert, source-node) route
    counts over a multi-step stream of RoutingTables. decay = 1.0 is
    pure counting; decay < 1.0 forgets old regimes geometrically."""

    def __init__(self, n_experts, n_nodes, decay):
        assert 0.0 < decay <= 1.0
        self.n_experts = n_experts
        self.n_nodes = n_nodes
        self.decay = decay
        self.counts = [0.0] * (n_experts * n_nodes)
        self.steps = 0

    def observe(self, rt, n_devices, devices_per_node):
        assert rt.n_experts == self.n_experts
        assert n_devices % devices_per_node == 0
        assert n_devices // devices_per_node == self.n_nodes
        tokens_per_device = -(-rt.n_tokens // n_devices)
        obs = [0] * (self.n_experts * self.n_nodes)
        for (t, kk, e, slot, w) in rt.routes:
            src = min(t // tokens_per_device, n_devices - 1)
            obs[e * self.n_nodes + src // devices_per_node] += 1
        for i in range(len(self.counts)):
            self.counts[i] = self.decay * self.counts[i] + float(obs[i])
        self.steps += 1

    def affinity(self, expert, node):
        return self.counts[expert * self.n_nodes + node]

    def packed(self, n_devices, devices_per_node):
        return affinity_packed_measured(self.counts, self.n_experts,
                                        n_devices, devices_per_node)


class MigrationPlan:
    """coordinator::replace::MigrationPlan — moves = (expert, from, to,
    bytes), one per expert whose device changed between placements."""

    def __init__(self, moves, n_devices):
        self.moves = moves
        self.n_devices = n_devices

    @staticmethod
    def between(old, new, bytes_per_expert):
        assert old.n_experts == new.n_experts
        assert old.n_devices == new.n_devices
        moves = []
        for e in range(old.n_experts):
            f, t = old.device_of(e), new.device_of(e)
            if f != t:
                moves.append((e, f, t, bytes_per_expert))
        return MigrationPlan(moves, old.n_devices)

    def is_empty(self):
        return not self.moves

    def total_bytes(self):
        return sum(m[3] for m in self.moves)

    def bytes_into(self, device):
        return sum(m[3] for m in self.moves if m[2] == device)

    def time(self, link):
        """Serialized per-destination-engine transfer time (the H2D
        engine of each receiving device runs its moves back to back);
        the plan completes when the slowest engine drains."""
        per = [0.0] * self.n_devices
        for (e, f, t, b) in self.moves:
            per[t] += transfer_time(link, b)
        worst = 0.0
        for x in per:
            worst = max(worst, x)
        return worst

    def add_h2d_tasks(self, sim, link):
        """One DES task per move on the destination device's H2D engine,
        dependency-free: transfers start at step begin and overlap the
        step's backbone compute."""
        return [sim.add(f"H2D-E{e}", h2d(t), transfer_time(link, b), [])
                for (e, f, t, b) in self.moves]


# ReplacePolicy: ('never',) | ('every', k) | ('break-even',)

def should_migrate(policy, step, remaining, saving, overhead):
    if policy[0] == 'never':
        return False
    if policy[0] == 'every':
        return (step + 1) % policy[1] == 0
    return saving > 0.0 and saving * float(remaining) > overhead


def drifting_node_affine_routing(n_devices, devices_per_node, n_experts,
                                 tokens_per_device, regime, noise, seed):
    """report::efficiency::drifting_node_affine_routing — k = 1
    node-affine routing with per-token noise: with probability `noise` a
    token picks a uniformly random expert instead of one from its node's
    affinity group. `regime` rotates the node->group mapping (a regime
    shift re-labels which experts each node is affine to)."""
    assert devices_per_node > 0 and n_devices % devices_per_node == 0
    n_nodes = n_devices // devices_per_node
    assert n_experts % n_nodes == 0
    group = n_experts // n_nodes
    n_tokens = n_devices * tokens_per_device
    rng = Rng(seed)
    indices = []
    weights = [1.0] * n_tokens
    for t in range(n_tokens):
        node = (t // tokens_per_device) // devices_per_node
        aff_node = (node + regime) % n_nodes
        if rng.next_f64() < noise:
            e = rng.below(n_experts)
        else:
            e = aff_node + n_nodes * rng.below(group)
        indices.append(e)
    return RoutingTable(indices, weights, n_tokens, 1, n_experts, n_tokens)


def run_replace_timeline(base, topo, token_bytes, tables, initial, kind,
                         strat, policy, bytes_per_expert, h2d_link, decay,
                         slot=0, pipelining=STAGED):
    """coordinator::replace::run_replace_timeline — per step: build the
    step's schedule under the placement in force, observe the step's
    routing, and (policy permitting) fire a migration to the measured
    packing whose H2D tasks overlap THIS step; the new placement takes
    effect from the NEXT step. Returns (steps, total, migrations) with
    steps = (step, makespan, base_makespan, migrated, bytes, mig_time)."""
    n_nodes = topo.n_devices // topo.devices_per_node
    est = AffinityEstimator(initial.n_experts, n_nodes, decay)
    placement = initial
    steps = []
    total = 0.0
    migrations = 0
    n_steps = len(tables)
    for s, rt in enumerate(tables):
        costs = topo_from_routing4(base, topo, rt, placement, token_bytes)
        sim = build_spec4(costs, kind, strat, slot, pipelining)
        base_makespan = sim.makespan()
        est.observe(rt, topo.n_devices, topo.devices_per_node)
        remaining = n_steps - s - 1
        migrated = False
        mig_bytes = 0
        mig_time = 0.0
        if remaining > 0 and policy[0] != 'never':
            candidate = est.packed(topo.n_devices, topo.devices_per_node)
            plan = MigrationPlan.between(placement, candidate,
                                         bytes_per_expert)
            if not plan.is_empty():
                # the H2D engines run concurrently with the step's
                # schedule, so the makespan cost of migrating is only
                # the part of the transfer that outlasts the step
                mig = plan.time(h2d_link)
                overhead = max(0.0, mig - base_makespan)
                if policy[0] == 'break-even':
                    cand_costs = topo_from_routing4(base, topo, rt, candidate,
                                                    token_bytes)
                    saving = base_makespan - build_spec4(
                        cand_costs, kind, strat, slot, pipelining).makespan()
                else:
                    saving = 0.0
                if should_migrate(policy, s, remaining, saving, overhead):
                    plan.add_h2d_tasks(sim, h2d_link)
                    migrated = True
                    mig_bytes = plan.total_bytes()
                    mig_time = mig
                    placement = candidate
                    migrations += 1
        # deterministic DES: only migration tasks can change the makespan
        makespan = sim.makespan() if migrated else base_makespan
        total += makespan
        steps.append((s, makespan, base_makespan, migrated, mig_bytes,
                      mig_time))
    return steps, total, migrations


# --- PR5 golden corpus additions --------------------------------------

REPLACE_H2D_LINK = LinkModel(0.125, 1024.0)
REPLACE_BYTES_PER_EXPERT = 4096


def generate_replace_lines5():
    """Migration-step goldens: the routed block-placement schedules with
    the block->affinity MigrationPlan's H2D tasks overlapped in (all
    dyadic: 0.125 + 4096/1024 = 4.125 s per moved expert)."""
    rt = routed_table3()
    block = Placement.block(4, 4)
    affinity = Placement.affinity_packed(rt, 4, 2)
    plan = MigrationPlan.between(block, affinity, REPLACE_BYTES_PER_EXPERT)
    tc = routed_fleet4(rt, block)
    lines = []
    for name, strat, slot in [('seq', ('seq',), 0),
                              ('overlap-s2', ('overlap',), 2),
                              ('pipe2', ('pipe', 2), 0)]:
        sim = build_spec4(tc, ('scmoe', 1), strat, slot)
        plan.add_h2d_tasks(sim, REPLACE_H2D_LINK)
        lines.append(render_line(f'replace:block->affinity/{name}', sim))
    return lines


def generate_corpus_lines5():
    return generate_corpus_lines4() + generate_replace_lines5()


def validate_corpus5():
    golden_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                               '..', '..', 'rust', 'tests', 'golden',
                               'timelines.txt')
    golden = [l for l in open(golden_path).read().splitlines()
              if l.strip() and not l.startswith('#')]
    lines = generate_corpus_lines5()
    bad = 0
    if len(golden) != len(lines):
        print(f'line-count mismatch: golden {len(golden)} vs mirror {len(lines)}')
        bad += 1
    for g, cu in zip(golden, lines):
        if g != cu:
            bad += 1
            print('- ' + g)
            print('+ ' + cu)
    print(f'golden corpus (PR5 model): {len(lines)} lines, {bad} mismatches')
    return bad == 0


def emit_corpus5(path):
    keep = CORPUS_HEADER3.splitlines()
    lines = generate_corpus_lines5()
    routed_at = next(i for i, l in enumerate(lines) if l.startswith('routed:'))
    routed_comment = [
        '# Routed-placement scenarios (dyadic 4-device/2-node fleet; see',
        '# routed_table/routed_fleet in golden_timelines.rs).',
    ]
    replace_at = next(i for i, l in enumerate(lines)
                      if l.startswith('replace:'))
    replace_comment = [
        '# Live re-placement migration steps: the routed block-placement',
        '# schedules with the block->affinity MigrationPlan overlapped in',
        '# as dependency-free H2D tasks (h<dev> rows; 4096 B/expert over',
        '# an alpha=0.125 beta=1024 H2D link -> 4.125 s per moved expert).',
        '# The pre-existing spans are byte-identical to the routed:block',
        '# entries above (pinned by mirror consistency_checks5).',
    ]
    body = (lines[:routed_at] + routed_comment + lines[routed_at:replace_at]
            + replace_comment + lines[replace_at:])
    with open(path, 'w') as f:
        f.write('\n'.join(keep) + '\n' + '\n'.join(body) + '\n')
    print(f'emitted {len(lines)} corpus lines to {path}')


def consistency_checks5():
    """Reductions the PR5 model must satisfy before its output is
    trusted as a golden value."""
    # 1. the measured packer over integer-valued f64 matrices reproduces
    #    the one-shot integer affinity_packed bit-exactly
    rt = routed_table3()
    for n_devices, dpn in [(4, 2), (4, 4)]:
        ref = Placement.affinity_packed(rt, n_devices, dpn)
        tokens_per_device = -(-rt.n_tokens // n_devices)
        aff = [0.0] * (rt.n_experts * (n_devices // dpn))
        for (t, kk, e, slot, w) in rt.routes:
            src = min(t // tokens_per_device, n_devices - 1)
            aff[e * (n_devices // dpn) + src // dpn] += 1.0
        got = affinity_packed_measured(aff, rt.n_experts, n_devices, dpn)
        assert got.map == ref.map, (n_devices, dpn, got.map, ref.map)
    # 2. a counting estimator over T identical tables packs identically
    #    to the one-shot packer (counts are an exact T-fold scaling)
    est = AffinityEstimator(4, 2, 1.0)
    for _ in range(3):
        est.observe(rt, 4, 2)
    assert est.steps == 3
    assert est.packed(4, 2).map == Placement.affinity_packed(rt, 4, 2).map
    # 3. migration byte accounting is exact: plan bytes = moved experts x
    #    per-expert bytes; the self-plan is empty
    block = Placement.block(4, 4)
    affinity = Placement.affinity_packed(rt, 4, 2)
    plan = MigrationPlan.between(block, affinity, 4096)
    moved = sum(1 for e in range(4)
                if block.device_of(e) != affinity.device_of(e))
    assert plan.total_bytes() == moved * 4096
    assert sum(plan.bytes_into(d) for d in range(4)) == plan.total_bytes()
    assert MigrationPlan.between(block, block, 4096).is_empty()
    # 4. H2D tasks never overlap on one engine in the migration goldens
    tc = routed_fleet4(rt, block)
    sim = build_spec4(tc, ('scmoe', 1), ('seq',), 0)
    MigrationPlan.between(block, affinity, 4096).add_h2d_tasks(
        sim, REPLACE_H2D_LINK)
    per_engine = {}
    for (i, label, res, start, end) in sim.run():
        if res[0] == 'h2d':
            per_engine.setdefault(res, []).append((start, end))
    assert per_engine, 'migration goldens must schedule H2D tasks'
    for spans in per_engine.values():
        spans.sort()
        for (s0, e0), (s1, e1) in zip(spans, spans[1:]):
            assert s1 >= e0 - 1e-12, 'H2D overlap'
    # 5. the migration golden is the base schedule plus appended H2D
    #    spans: every pre-existing task keeps its exact span
    base_sim = build_spec4(tc, ('scmoe', 1), ('seq',), 0)
    base_spans = base_sim.run()
    mig_spans = sim.run()
    for b, m in zip(base_spans, mig_spans[:len(base_spans)]):
        assert b == m, 'migration tasks perturbed the step schedule'
    # 6. a Never-policy multi-step timeline over constant tables reduces
    #    to N independent single-step schedules, bit-exactly
    topo = Topology(4, 2, LinkModel(0.0625, 1024.0), LinkModel(0.125, 512.0),
                    1.0, None)
    base = ComputeCosts(1.0, 0.75, 0.75, 0.0625, 0.0625, 0.0625, 0.5)
    single = build_spec4(routed_fleet4(rt, block), ('scmoe', 1), ('seq',),
                         0).makespan()
    steps, total, migrations = run_replace_timeline(
        base, topo, 64, [rt] * 4, block, ('scmoe', 1), ('seq',), ('never',),
        4096, REPLACE_H2D_LINK, 1.0)
    assert migrations == 0
    for (s, makespan, base_makespan, migrated, mb, mt) in steps:
        assert makespan == single and base_makespan == single
        assert not migrated and mb == 0 and mt == 0.0
    print('PR5 consistency checks: OK')


# --- PR5 study scenarios (the numbers pinned in rust/tests/ -----------
# replace_timeline.rs and quoted in docs/STUDIES.md are minted here) ---

REPLACE_STUDY_TOKENS = 640
REPLACE_STUDY_BYTES = 8192
REPLACE_STUDY_EXPERT_BYTES = 128 * 1024 * 1024
REPLACE_STUDY_H2D = LinkModel(10e-6, 16e9)
REPLACE_STUDY_STEPS = 16
REPLACE_STUDY_SHIFT = 8


def replace_drift_tables(noise, seed0, shift_at=None):
    """One table per step: node-affine with per-token noise; steps at or
    beyond `shift_at` rotate the node->group regime by one."""
    tables = []
    for s in range(REPLACE_STUDY_STEPS):
        regime = 1 if (shift_at is not None and s >= shift_at) else 0
        tables.append(drifting_node_affine_routing(
            32, 8, 32, REPLACE_STUDY_TOKENS, regime, noise, seed0 + s))
    return tables


def replace_study5():
    topo = SCENARIOS['4node-ib']
    base = xl_compute_costs()
    blk = Placement.block(32, 32)
    run = lambda tables, policy, decay: run_replace_timeline(
        base, topo, REPLACE_STUDY_BYTES, tables, blk, ('scmoe', 1), ('seq',),
        policy, REPLACE_STUDY_EXPERT_BYTES, REPLACE_STUDY_H2D, decay)
    # scenario A: stable drift, counting estimator, break-even vs static
    ta = replace_drift_tables(0.05, 11)
    st_n, tot_n, _ = run(ta, ('never',), 1.0)
    st_b, tot_b, mig_b = run(ta, ('break-even',), 1.0)
    cum_n = cum_b = 0.0
    be = None
    for (sn, sb) in zip(st_n, st_b):
        cum_n += sn[1]
        cum_b += sb[1]
        if be is None and cum_b < cum_n:
            be = sn[0] + 1
    print('A(drift):  static %.6f ms | replace %.6f ms | migrations %d | '
          'break-even at %d steps' % (tot_n * 1e3, tot_b * 1e3, mig_b, be))
    # scenario B: regime shift at step 8, EWMA 0.5, eager vs threshold
    tb = replace_drift_tables(0.15, 211, shift_at=REPLACE_STUDY_SHIFT)
    for pol in [('never',), ('every', 1), ('break-even',)]:
        st, tot, mig = run(tb, pol, 0.5)
        marks = ''.join('M' if s[3] else '.' for s in st)
        print('B(shift):  %-10s total %.6f ms migrations %2d  %s'
              % (pol[0], tot * 1e3, mig, marks))


# ======================================================================
# PR 6 model: the open-loop serving loop (request streams -> batches ->
# priced DES steps -> latencies). Transcribes the post-PR6 Rust
# line-by-line:
#   moe/traffic.rs         -> phase_affine_routing
#   serve/arrivals.rs      -> poisson_arrivals (Bernoulli-grid thinning)
#   serve/batch.rs         -> batch_decide
#   serve/engine.rs        -> run_serve
#   util/stats.rs          -> percentile (nearest-rank, f64::round)
#   report/serve_report.rs -> SERVE_* constants + serve_cell + knee
# ======================================================================


def phase_affine_routing(n_devices, devices_per_node, n_experts,
                         prefill_tokens, decode_tokens, regime,
                         prefill_noise, decode_noise, seed):
    """moe::traffic::phase_affine_routing — mixed-batch node-affine
    routing (k = 1): the first `prefill_tokens` positions roll their
    noise against `prefill_noise`, the rest against `decode_noise`.
    drifting_node_affine_routing is the equal-noise, evenly-divisible
    special case, bit-exactly (same splitmix64 draw order: one next_f64
    per token plus one below() on the taken branch)."""
    assert devices_per_node > 0 and n_devices % devices_per_node == 0
    n_nodes = n_devices // devices_per_node
    assert n_experts % n_nodes == 0
    group = n_experts // n_nodes
    n_tokens = prefill_tokens + decode_tokens
    assert n_tokens > 0
    tokens_per_device = -(-n_tokens // n_devices)
    rng = Rng(seed)
    indices = []
    weights = [1.0] * n_tokens
    for t in range(n_tokens):
        node = min(t // tokens_per_device, n_devices - 1) // devices_per_node
        aff_node = (node + regime) % n_nodes
        noise = prefill_noise if t < prefill_tokens else decode_noise
        if rng.next_f64() < noise:
            e = rng.below(n_experts)
        else:
            e = aff_node + n_nodes * rng.below(group)
        indices.append(e)
    return RoutingTable(indices, weights, n_tokens, 1, n_experts, n_tokens)


def poisson_arrivals(n_requests, rate, tick, prefill_tokens, decode_steps,
                     seed):
    """serve::arrivals::poisson_arrivals — Bernoulli thinning on a fixed
    tick grid (each tick admits with p = rate*tick): geometric gaps with
    mean 1/rate, no ln(), bit-reproducible against Rust. Requests are
    (arrival, prefill_tokens, decode_steps) tuples (ids are implicit
    arrival-order indices on both sides)."""
    assert rate > 0.0 and tick > 0.0
    p = rate * tick
    assert p < 1.0
    rng = Rng(seed)
    out = []
    i = 0
    while len(out) < n_requests:
        if rng.next_f64() < p:
            out.append((float(i) * tick, prefill_tokens, decode_steps))
        i += 1
    return out


# BatchPolicy: ('wait', k) | ('deadline', window) | ('budget', budget)
# BatchDecision: ('admit', n) | ('wait-until', t)

def batch_decide(policy, now, queued, active, decode_tokens, next_arrival):
    """serve::batch::BatchPolicy::decide — queued is the FIFO prefill
    queue as (arrival, prefill_tokens) rows; active counts in-flight
    decode requests."""
    if policy[0] == 'wait':
        k = policy[1]
        assert k > 0
        if len(queued) >= k:
            return ('admit', k)
        if active > 0:
            return ('admit', len(queued))
        if next_arrival is not None:
            return ('wait-until', next_arrival)
        return ('admit', len(queued))  # tail drain
    if policy[0] == 'deadline':
        window = policy[1]
        if not queued:
            return ('admit', 0)  # pure-decode step
        deadline = queued[0][0] + window
        if now >= deadline:
            return ('admit', len(queued))
        if active > 0:
            return ('admit', 0)
        if next_arrival is not None and next_arrival < deadline:
            return ('wait-until', next_arrival)
        return ('wait-until', deadline)
    budget = policy[1]
    tokens = active * decode_tokens
    n = 0
    for (arr, prefill) in queued:
        if tokens + prefill > budget:
            break
        tokens += prefill
        n += 1
    if n == 0 and active == 0:
        return ('admit', 1)  # oversized head runs alone
    return ('admit', n)


def percentile(xs, p):
    """util::stats::percentile — nearest-rank on a sorted copy. Rust
    rounds the rank with f64::round (half away from zero): transcribed
    via rust_round, NOT Python round() (banker's rounding diverges on
    every odd-length median)."""
    if not xs:
        return 0.0
    v = sorted(xs)
    rank = rust_round((p / 100.0) * (len(v) - 1.0))
    return v[min(rank, len(v) - 1)]


def run_serve(base, topo, requests, initial, kind, strat, batching, policy,
              decay, bytes_per_expert, h2d_link, token_bytes, decode_tokens,
              n_experts, regime, shift_at, prefill_noise, decode_noise,
              traffic_seed, slot=0, pipelining=STAGED):
    """serve::engine::run_serve — drain arrivals, ask the batch policy,
    price the admitted batch's phase-affine table under the placement in
    force, run the PR5 migration decision with remaining = outstanding
    requests, record completions. Returns (steps, latencies, busy,
    total_time, migrations, final_placement) with steps = (step, start,
    makespan, base_makespan, prefills, prefill_tokens, decodes,
    decode_tokens, queued, migrated, mig_bytes, mig_time, completed)."""
    assert requests
    assert all(a[0] <= b[0] for a, b in zip(requests, requests[1:]))
    assert all(r[2] == 0 for r in requests) or decode_tokens > 0
    assert n_experts == initial.n_experts
    n_nodes = topo.n_devices // topo.devices_per_node
    est = AffinityEstimator(n_experts, n_nodes, decay)
    placement = initial
    queued = []   # (arrival, prefill_tokens, decode_steps)
    active = []   # (arrival, remaining_decode)
    next_idx = 0
    now = 0.0
    step = 0
    steps = []
    latencies = []
    busy = 0.0
    migrations = 0
    while next_idx < len(requests) or queued or active:
        while next_idx < len(requests) and requests[next_idx][0] <= now:
            queued.append(requests[next_idx])
            next_idx += 1
        if not queued and not active:
            now = requests[next_idx][0]  # idle: jump to next arrival
            continue
        next_arrival = (requests[next_idx][0] if next_idx < len(requests)
                        else None)
        qmeta = [(r[0], r[1]) for r in queued]
        dec = batch_decide(batching, now, qmeta, len(active), decode_tokens,
                           next_arrival)
        if dec[0] == 'wait-until':
            assert dec[1] > now, 'batching must advance the clock'
            now = dec[1]
            continue
        admit = dec[1]
        admitted = queued[:admit]
        queued = queued[admit:]
        n_prefill_tokens = sum(r[1] for r in admitted)
        n_decodes = len(active)
        n_decode_tokens = n_decodes * decode_tokens
        reg = regime + (1 if (shift_at is not None and step >= shift_at)
                        else 0)
        rt = phase_affine_routing(topo.n_devices, topo.devices_per_node,
                                  n_experts, n_prefill_tokens,
                                  n_decode_tokens, reg, prefill_noise,
                                  decode_noise, traffic_seed + step)
        costs = topo_from_routing4(base, topo, rt, placement, token_bytes)
        sim = build_spec4(costs, kind, strat, slot, pipelining)
        base_makespan = sim.makespan()
        est.observe(rt, topo.n_devices, topo.devices_per_node)
        survivors = (sum(1 for a in active if a[1] > 1)
                     + sum(1 for r in admitted if r[2] > 0))
        remaining = (len(requests) - next_idx) + len(queued) + survivors
        migrated = False
        mig_bytes = 0
        mig_time = 0.0
        if remaining > 0 and policy[0] != 'never':
            candidate = est.packed(topo.n_devices, topo.devices_per_node)
            plan = MigrationPlan.between(placement, candidate,
                                         bytes_per_expert)
            if not plan.is_empty():
                mig = plan.time(h2d_link)
                overhead = max(0.0, mig - base_makespan)
                if policy[0] == 'break-even':
                    cand_costs = topo_from_routing4(base, topo, rt, candidate,
                                                    token_bytes)
                    saving = base_makespan - build_spec4(
                        cand_costs, kind, strat, slot, pipelining).makespan()
                else:
                    saving = 0.0
                if should_migrate(policy, step, remaining, saving, overhead):
                    plan.add_h2d_tasks(sim, h2d_link)
                    migrated = True
                    mig_bytes = plan.total_bytes()
                    mig_time = mig
                    placement = candidate
                    migrations += 1
        makespan = sim.makespan() if migrated else base_makespan
        end = now + makespan
        completed = 0
        still = []
        for (arr, rem) in active:
            if rem == 1:
                latencies.append(end - arr)
                completed += 1
            else:
                still.append((arr, rem - 1))
        active = still
        for (arr, pf, ds) in admitted:
            if ds == 0:
                latencies.append(end - arr)
                completed += 1
            else:
                active.append((arr, ds))
        steps.append((step, now, makespan, base_makespan, admit,
                      n_prefill_tokens, n_decodes, n_decode_tokens,
                      len(queued), migrated, mig_bytes, mig_time, completed))
        busy += makespan
        now = end
        step += 1
    return steps, latencies, busy, now, migrations, placement


# --- PR6 golden corpus additions --------------------------------------

def generate_serve_lines6():
    """Serving-step goldens: phase-affine mixed batches priced on the
    dyadic routed fleet under the block placement (seq ScMoE spec). The
    wait1 triple pins the per-step seed advance of the serving loop's
    traffic stream; the mixed line pins the two-noise phase split."""
    block = Placement.block(4, 4)
    lines = []
    for s in range(3):
        rt = phase_affine_routing(4, 2, 4, 16, 0, 0, 0.25, 0.25, 97 + s)
        sim = build_spec4(routed_fleet4(rt, block), ('scmoe', 1), ('seq',), 0)
        lines.append(render_line(f'serve:wait1/step{s}', sim))
    rt = phase_affine_routing(4, 2, 4, 8, 8, 0, 0.0, 0.5, 98)
    sim = build_spec4(routed_fleet4(rt, block), ('scmoe', 1), ('seq',), 0)
    lines.append(render_line('serve:mixed/seq', sim))
    return lines


def generate_corpus_lines6():
    return generate_corpus_lines5() + generate_serve_lines6()


def validate_corpus6():
    golden_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                               '..', '..', 'rust', 'tests', 'golden',
                               'timelines.txt')
    golden = [l for l in open(golden_path).read().splitlines()
              if l.strip() and not l.startswith('#')]
    lines = generate_corpus_lines6()
    bad = 0
    if len(golden) != len(lines):
        print(f'line-count mismatch: golden {len(golden)} vs mirror {len(lines)}')
        bad += 1
    for g, cu in zip(golden, lines):
        if g != cu:
            bad += 1
            print('- ' + g)
            print('+ ' + cu)
    print(f'golden corpus (PR6 model): {len(lines)} lines, {bad} mismatches')
    return bad == 0


def emit_corpus6(path):
    keep = CORPUS_HEADER3.splitlines()
    lines = generate_corpus_lines6()
    routed_at = next(i for i, l in enumerate(lines) if l.startswith('routed:'))
    routed_comment = [
        '# Routed-placement scenarios (dyadic 4-device/2-node fleet; see',
        '# routed_table/routed_fleet in golden_timelines.rs).',
    ]
    replace_at = next(i for i, l in enumerate(lines)
                      if l.startswith('replace:'))
    replace_comment = [
        '# Live re-placement migration steps: the routed block-placement',
        '# schedules with the block->affinity MigrationPlan overlapped in',
        '# as dependency-free H2D tasks (h<dev> rows; 4096 B/expert over',
        '# an alpha=0.125 beta=1024 H2D link -> 4.125 s per moved expert).',
        '# The pre-existing spans are byte-identical to the routed:block',
        '# entries above (pinned by mirror consistency_checks5).',
    ]
    serve_at = next(i for i, l in enumerate(lines) if l.startswith('serve:'))
    serve_comment = [
        '# Open-loop serving steps: phase_affine_routing batches priced',
        '# on the routed fleet under the block placement. serve:wait1/*',
        '# pins the serving loop\'s per-step traffic-seed advance (seeds',
        '# 97..99, uniform noise 0.25); serve:mixed pins the prefill/',
        '# decode noise split (8 exact prompt tokens + 8 tokens at 0.5).',
    ]
    body = (lines[:routed_at] + routed_comment + lines[routed_at:replace_at]
            + replace_comment + lines[replace_at:serve_at]
            + serve_comment + lines[serve_at:])
    with open(path, 'w') as f:
        f.write('\n'.join(keep) + '\n' + '\n'.join(body) + '\n')
    print(f'emitted {len(lines)} corpus lines to {path}')


# --- PR6 study scenario (the numbers pinned in rust/tests/ ------------
# serve_loop.rs and quoted in docs/STUDIES.md are minted here) ---------

SERVE_REQUESTS = 64
SERVE_PREFILL_TOKENS = 2048
SERVE_DECODE_STEPS = 4
SERVE_DECODE_TOKENS = 64
SERVE_TOKEN_BYTES = 8192
SERVE_TICK = 1.0 / 2048.0
SERVE_SEED = 31
SERVE_TRAFFIC_SEED = 311
SERVE_PREFILL_NOISE = 0.05
SERVE_DECODE_NOISE = 0.25
SERVE_BUDGET = 6144
SERVE_SLO = 0.030
SERVE_OVERLAP_SLOT = 2
SERVE_LOADS = [120.0, 240.0, 480.0]


def serve_cell(rate, strat, batching, policy):
    """report::serve_report::run_serve_cell — one sweep cell on the
    4-node IB preset with the GPT3-XL payload, from the uniform block
    placement."""
    topo = SCENARIOS['4node-ib']
    base = xl_compute_costs()
    requests = poisson_arrivals(SERVE_REQUESTS, rate, SERVE_TICK,
                                SERVE_PREFILL_TOKENS, SERVE_DECODE_STEPS,
                                SERVE_SEED)
    slot = SERVE_OVERLAP_SLOT if strat[0] == 'overlap' else 0
    return run_serve(base, topo, requests, Placement.block(32, 32),
                     ('scmoe', 1), strat, batching, policy, 1.0,
                     REPLACE_STUDY_EXPERT_BYTES, REPLACE_STUDY_H2D,
                     SERVE_TOKEN_BYTES, SERVE_DECODE_TOKENS, 32,
                     0, None, SERVE_PREFILL_NOISE, SERVE_DECODE_NOISE,
                     SERVE_TRAFFIC_SEED, slot)


def serve_study6():
    """Full-precision pinned numbers for rust/tests/serve_loop.rs and
    docs/STUDIES.md (repr() round-trips the exact f64)."""
    budget = ('budget', SERVE_BUDGET)
    for strat in [('seq',), ('overlap',)]:
        for policy in [('never',), ('break-even',)]:
            knee = None
            for rate in SERVE_LOADS:
                steps, lat, busy, total, mig, _ = serve_cell(
                    rate, strat, budget, policy)
                p50 = percentile(lat, 50.0)
                p99 = percentile(lat, 99.0)
                thr = len(lat) / total
                good = sum(1 for l in lat if l <= SERVE_SLO) / total
                print('load %5.0f %-7s %-10s steps %3d migr %2d' %
                      (rate, strat[0], policy[0], len(steps), mig))
                print('  p50 %r p99 %r' % (p50, p99))
                print('  req/s %r goodput %r busy %r total %r' %
                      (thr, good, busy, total))
                if p99 <= SERVE_SLO:
                    knee = rate if knee is None else max(knee, rate)
            print('  knee: %r' % knee)
    print('-- batching policies at %.0f req/s (seq, break-even) --'
          % SERVE_LOADS[1])
    for batching in [('wait', 2), ('deadline', 0.008), budget]:
        steps, lat, busy, total, mig, _ = serve_cell(
            SERVE_LOADS[1], ('seq',), batching, ('break-even',))
        print('%-16s steps %3d migr %2d p50 %r p99 %r req/s %r goodput %r'
              % (batching, len(steps), mig, percentile(lat, 50.0),
                 percentile(lat, 99.0), len(lat) / total,
                 sum(1 for l in lat if l <= SERVE_SLO) / total))


def consistency_checks6():
    """Reductions the PR6 model must satisfy before its output is
    trusted as a golden or pinned value."""
    # 1. the phase-affine generator degenerates to the PR5 drifting
    #    generator bit-exactly when both noises coincide and the token
    #    count divides evenly (same draw order per token)
    for (regime, noise, seed) in [(0, 0.0, 3), (0, 0.25, 97), (1, 0.6, 42)]:
        a = drifting_node_affine_routing(4, 2, 4, 4, regime, noise, seed)
        b = phase_affine_routing(4, 2, 4, 16, 0, regime, noise, noise, seed)
        assert a.routes == b.routes and a.load == b.load
    # 2. nearest-rank percentile follows Rust f64::round (half away from
    #    zero), not Python banker's rounding: the 4-element median picks
    #    the upper neighbour
    assert percentile([1.0, 2.0, 3.0, 4.0], 50.0) == 3.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 100.0) == 4.0
    assert percentile([], 50.0) == 0.0
    # 3. the arrival grid is deterministic, sorted, and respects the
    #    thinning probability bound
    a = poisson_arrivals(32, 100.0, 1.0 / 2048.0, 128, 4, 7)
    b = poisson_arrivals(32, 100.0, 1.0 / 2048.0, 128, 4, 7)
    assert a == b and len(a) == 32
    assert all(x[0] <= y[0] for x, y in zip(a, a[1:]))
    # 4. batch policies reproduce the Rust unit-test vectors
    assert batch_decide(('wait', 2), 0.0, [(0.0, 64)], 0, 8, 0.5) == \
        ('wait-until', 0.5)
    assert batch_decide(('wait', 2), 0.5, [(0.0, 64), (0.5, 64)], 0, 8,
                        None) == ('admit', 2)
    assert batch_decide(('wait', 2), 0.0, [(0.0, 64)], 3, 8, 0.5) == \
        ('admit', 1)
    assert batch_decide(('wait', 2), 0.0, [(0.0, 64)], 0, 8, None) == \
        ('admit', 1)
    assert batch_decide(('deadline', 0.25), 1.1, [(1.0, 64), (1.1, 64)], 0,
                        8, 1.2) == ('wait-until', 1.2)
    assert batch_decide(('deadline', 0.25), 1.1, [(1.0, 64), (1.1, 64)], 0,
                        8, 2.0) == ('wait-until', 1.25)
    assert batch_decide(('deadline', 0.25), 1.25, [(1.0, 64), (1.1, 64)], 0,
                        8, 2.0) == ('admit', 2)
    assert batch_decide(('deadline', 0.25), 1.1, [(1.0, 64), (1.1, 64)], 2,
                        8, 2.0) == ('admit', 0)
    q3 = [(0.0, 100), (0.0, 100), (0.0, 100)]
    assert batch_decide(('budget', 256), 0.0, q3, 4, 16, None) == ('admit', 1)
    assert batch_decide(('budget', 256), 0.0, q3, 0, 16, None) == ('admit', 2)
    assert batch_decide(('budget', 256), 0.0, [(0.0, 1000)], 0, 16, None) == \
        ('admit', 1)
    assert batch_decide(('budget', 256), 0.0, [(0.0, 1000)], 4, 16, None) == \
        ('admit', 0)
    # 5. closed-system reduction: all requests at t=0, wait-1 batching,
    #    prefill-only -> the serving loop IS run_replace_timeline over
    #    the same drifting table stream, bit-exactly (dyadic config)
    topo = Topology(4, 2, LinkModel(0.0625, 1024.0), LinkModel(0.125, 512.0),
                    1.0, None)
    base = ComputeCosts(1.0, 0.75, 0.75, 0.0625, 0.0625, 0.0625, 0.5)
    n = 6
    tables = [drifting_node_affine_routing(4, 2, 4, 4, 0, 0.25, 500 + s)
              for s in range(n)]
    block = Placement.block(4, 4)
    for policy in [('never',), ('break-even',)]:
        ref_steps, ref_total, ref_mig = run_replace_timeline(
            base, topo, 64, tables, block, ('scmoe', 1), ('seq',), policy,
            4096, REPLACE_H2D_LINK, 1.0)
        steps, lat, busy, total, mig, _ = run_serve(
            base, topo, [(0.0, 16, 0)] * n, block, ('scmoe', 1), ('seq',),
            ('wait', 1), policy, 1.0, 4096, REPLACE_H2D_LINK, 64, 0, 4,
            0, None, 0.25, 0.25, 500)
        assert mig == ref_mig and total == ref_total and busy == total
        assert len(steps) == n and len(lat) == n
        for (sv, rf) in zip(steps, ref_steps):
            # (step, makespan, base_makespan, migrated, bytes, time)
            assert sv[0] == rf[0] and sv[2] == rf[1] and sv[3] == rf[2]
            assert sv[9] == rf[3] and sv[10] == rf[4] and sv[11] == rf[5]
            assert sv[4] == 1 and sv[5] == 16 and sv[6] == 0 and sv[7] == 0
    # 6. the serving loop is deterministic: one seed, one outcome
    x = serve_cell(SERVE_LOADS[0], ('seq',), ('budget', SERVE_BUDGET),
                   ('never',))
    y = serve_cell(SERVE_LOADS[0], ('seq',), ('budget', SERVE_BUDGET),
                   ('never',))
    assert x[0] == y[0] and x[1] == y[1] and x[4] == y[4]
    print('PR6 consistency checks: OK')


# ======================================================================
# PR 7 model: chaos perturbations (per-device jitter + stragglers,
# degraded/flapping links, device dropout with expert failover) and C2R
# collaboration-constrained routing. Transcribes the post-PR7 Rust
# line-by-line:
#   util/rng.rs            -> Rng.fork (rng_fork7)
#   cluster/chaos.rs       -> LinkFault/Dropout/ChaosSpec + perturb
#   moe/traffic.rs         -> c2r_routing
#   coordinator/replace.rs -> failover_placement, run_chaos_timeline
#   report/chaos.rs        -> CHAOS_* constants + chaos_study7
# ======================================================================


def rng_fork7(rng, stream):
    """util::rng::Rng::fork — child stream seeded off the parent state
    (state ^ stream * 0xA0761D6478BD642F through the constructor, then
    one warm-up draw). Stable under reordering of other draws."""
    child = Rng((rng.state ^ ((stream * 0xA0761D6478BD642F) & MASK)) & MASK)
    child.next_u64()
    return child


@_dataclass
class LinkFault7:
    node: object        # None = shared uplink; int = that node's intra link
    alpha_mult: float   # launch latency multiplier while the fault is active
    beta_div: float     # bandwidth divisor while the fault is active
    flap: object        # None = persistent; (period, up) = degraded on
                        # steps with step % period >= up


def fault_active7(fault, step):
    if fault.flap is None:
        return True
    period, up = fault.flap
    return step % period >= up


@_dataclass
class Dropout7:
    device: int
    at_step: int


@_dataclass
class ChaosSpec7:
    seed: int           # jitter stream seed (forked per step)
    jitter: float       # max fractional per-device slowdown per step
    stragglers: list    # (device, persistent slowdown factor) pairs
    link_faults: list   # LinkFault7 entries
    dropout: object     # Dropout7 or None


def chaos_clean7(seed):
    return ChaosSpec7(seed, 0.0, [], [], None)


def chaos_is_zero7(spec):
    return (spec.jitter == 0.0
            and all(f == 1.0 for (_, f) in spec.stragglers)
            and all(f.alpha_mult == 1.0 and f.beta_div == 1.0
                    for f in spec.link_faults)
            and spec.dropout is None)


def chaos_perturb7(spec, topo, step, node_intra=None):
    """cluster::chaos::ChaosSpec::perturb — Rust clones the Topology and
    rewrites device_scales / node_intra / inter; the Python Topology
    dataclass has no node_intra field, so the per-node link vector rides
    alongside as a second return value (feeds topo_from_routing4's
    node_intra parameter). Fields a zero-magnitude spec never touches
    stay untouched, which is what makes the zero-perturbation identity
    bit-exact rather than merely value-equal."""
    scales = None
    straggling = any(f != 1.0 for (_, f) in spec.stragglers)
    if spec.jitter > 0.0 or straggling:
        scales = [topo.device_compute_scale(d) for d in range(topo.n_devices)]
        if spec.jitter > 0.0:
            rng = rng_fork7(Rng(spec.seed), step)
            for d in range(topo.n_devices):
                scales[d] /= 1.0 + spec.jitter * rng.next_f64()
        for (d, f) in spec.stragglers:
            scales[d] /= f
    links = topo_intra_links(topo, node_intra)
    inter = topo.inter
    touched_intra = False
    for f in spec.link_faults:
        if (f.alpha_mult == 1.0 and f.beta_div == 1.0) \
                or not fault_active7(f, step):
            continue
        if f.node is None:
            assert inter is not None, \
                'uplink fault on a single-node topology'
            inter = LinkModel(inter.alpha * f.alpha_mult,
                              inter.beta / f.beta_div)
        else:
            if not touched_intra:
                links = list(links)
            l = links[f.node]
            links[f.node] = LinkModel(l.alpha * f.alpha_mult,
                                      l.beta / f.beta_div)
            touched_intra = True
    out = replace(topo, inter=inter,
                  device_scales=scales if scales is not None
                  else topo.device_scales)
    return out, (links if touched_intra else node_intra)


def failover_placement7(p, failed):
    """coordinator::replace::failover_placement — deterministic expert
    failover: each of the failed device's experts (ascending id) goes to
    the least-loaded surviving device, ties toward the lower device id,
    with the running load updated after every reassignment."""
    assert p.n_devices > 1
    load = [0] * p.n_devices
    mapping = [p.device_of(e) for e in range(p.n_experts)]
    for d in mapping:
        load[d] += 1
    for e in range(p.n_experts):
        if mapping[e] != failed:
            continue
        load[failed] -= 1
        best = None
        best_load = None
        for d in range(p.n_devices):
            if d == failed:
                continue
            if best is None or load[d] < best_load:
                best = d
                best_load = load[d]
        mapping[e] = best
        load[best] += 1
    return Placement(p.n_experts, p.n_devices, mapping)


def c2r_routing(n_devices, devices_per_node, n_experts, tokens_per_device,
                regime, noise, collab, seed):
    """moe::traffic::c2r_routing — C2R-style (arXiv:2504.01337)
    collaboration-constrained node-affine routing (k = 1): deviating
    tokens are confined to the first `collab` experts of their node's
    affinity group instead of scattering uniformly over all experts, so
    worst-case A2A fanout stays bounded. Same per-token draw order as
    drifting_node_affine_routing (one next_f64, one below), to which it
    reduces bit-exactly at noise = 0."""
    assert devices_per_node > 0 and n_devices % devices_per_node == 0
    n_nodes = n_devices // devices_per_node
    assert n_experts % n_nodes == 0
    group = n_experts // n_nodes
    assert 1 <= collab <= group
    n_tokens = n_devices * tokens_per_device
    rng = Rng(seed)
    indices = []
    weights = [1.0] * n_tokens
    for t in range(n_tokens):
        node = (t // tokens_per_device) // devices_per_node
        aff_node = (node + regime) % n_nodes
        if rng.next_f64() < noise:
            e = aff_node + n_nodes * rng.below(collab)
        else:
            e = aff_node + n_nodes * rng.below(group)
        indices.append(e)
    return RoutingTable(indices, weights, n_tokens, 1, n_experts, n_tokens)


def run_chaos_timeline7(base, topo, token_bytes, tables, initial, kind,
                        strat, policy, bytes_per_expert, h2d_link, decay,
                        chaos, node_intra=None, slot=0, pipelining=STAGED):
    """coordinator::replace::run_chaos_timeline — run_replace_timeline
    with a per-step perturbed topology and dropout-aware placement flow:
    on the dropout step the failover plan fires unconditionally (its H2D
    storm overlaps that step; the recovered placement takes effect from
    the next step), and later policy candidates are remapped off the
    dead device. A zero-magnitude spec reduces bit-exactly to
    run_replace_timeline (consistency_checks7)."""
    n_nodes = topo.n_devices // topo.devices_per_node
    est = AffinityEstimator(initial.n_experts, n_nodes, decay)
    placement = initial
    steps = []
    total = 0.0
    migrations = 0
    n_steps = len(tables)
    for s, rt in enumerate(tables):
        ptopo, pni = chaos_perturb7(chaos, topo, s, node_intra)
        costs = topo_from_routing4(base, ptopo, rt, placement, token_bytes,
                                   pni)
        sim = build_spec4(costs, kind, strat, slot, pipelining)
        base_makespan = sim.makespan()
        est.observe(rt, topo.n_devices, topo.devices_per_node)
        remaining = n_steps - s - 1
        migrated = False
        mig_bytes = 0
        mig_time = 0.0
        failing = chaos.dropout is not None and chaos.dropout.at_step == s
        if failing:
            candidate = failover_placement7(placement, chaos.dropout.device)
            plan = MigrationPlan.between(placement, candidate,
                                         bytes_per_expert)
            if not plan.is_empty():
                mig_time = plan.time(h2d_link)
                plan.add_h2d_tasks(sim, h2d_link)
                migrated = True
                mig_bytes = plan.total_bytes()
                migrations += 1
            placement = candidate
        elif remaining > 0 and policy[0] != 'never':
            candidate = est.packed(topo.n_devices, topo.devices_per_node)
            if chaos.dropout is not None and s > chaos.dropout.at_step:
                candidate = failover_placement7(candidate,
                                                chaos.dropout.device)
            plan = MigrationPlan.between(placement, candidate,
                                         bytes_per_expert)
            if not plan.is_empty():
                mig = plan.time(h2d_link)
                overhead = max(0.0, mig - base_makespan)
                if policy[0] == 'break-even':
                    cand_costs = topo_from_routing4(base, ptopo, rt,
                                                    candidate, token_bytes,
                                                    pni)
                    saving = base_makespan - build_spec4(
                        cand_costs, kind, strat, slot, pipelining).makespan()
                else:
                    saving = 0.0
                if should_migrate(policy, s, remaining, saving, overhead):
                    plan.add_h2d_tasks(sim, h2d_link)
                    migrated = True
                    mig_bytes = plan.total_bytes()
                    mig_time = mig
                    placement = candidate
                    migrations += 1
        makespan = sim.makespan() if migrated else base_makespan
        total += makespan
        steps.append((s, makespan, base_makespan, migrated, mig_bytes,
                      mig_time))
    return steps, total, migrations


# --- PR7 golden corpus additions --------------------------------------

def generate_chaos_lines7():
    """Chaos goldens on the dyadic routed fleet, all rng-free so every
    span stays dyadic-exact: a persistent 2x straggler on device 3, a
    degraded shared uplink (alpha x2, beta /4 -> LinkModel(0.25, 128)),
    and a device-3 dropout whose failover plan (E3 -> device 0, the
    lowest-id tie) overlaps the step as an H2D task."""
    rt = routed_table3()
    block = Placement.block(4, 4)
    topo = Topology(4, 2, LinkModel(0.0625, 1024.0), LinkModel(0.125, 512.0),
                    1.0, None)
    base = ComputeCosts(1.0, 0.75, 0.75, 0.0625, 0.0625, 0.0625, 0.5)
    lines = []
    spec = ChaosSpec7(0, 0.0, [(3, 2.0)], [], None)
    pt, pni = chaos_perturb7(spec, topo, 0)
    sim = build_spec4(topo_from_routing4(base, pt, rt, block, 64, pni),
                      ('scmoe', 1), ('seq',), 0)
    lines.append(render_line('chaos:straggler/seq', sim))
    spec = ChaosSpec7(0, 0.0, [], [LinkFault7(None, 2.0, 4.0, None)], None)
    pt, pni = chaos_perturb7(spec, topo, 0)
    sim = build_spec4(topo_from_routing4(base, pt, rt, block, 64, pni),
                      ('scmoe', 1), ('overlap',), 2)
    lines.append(render_line('chaos:degraded-uplink/overlap-s2', sim))
    failover = failover_placement7(block, 3)
    plan = MigrationPlan.between(block, failover, REPLACE_BYTES_PER_EXPERT)
    sim = build_spec4(topo_from_routing4(base, topo, rt, block, 64),
                      ('scmoe', 1), ('seq',), 0)
    plan.add_h2d_tasks(sim, REPLACE_H2D_LINK)
    lines.append(render_line('chaos:dropout-recovery/seq', sim))
    return lines


def generate_corpus_lines7():
    return generate_corpus_lines6() + generate_chaos_lines7()


def validate_corpus7():
    golden_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                               '..', '..', 'rust', 'tests', 'golden',
                               'timelines.txt')
    golden = [l for l in open(golden_path).read().splitlines()
              if l.strip() and not l.startswith('#')]
    lines = generate_corpus_lines7()
    bad = 0
    if len(golden) != len(lines):
        print(f'line-count mismatch: golden {len(golden)} vs mirror {len(lines)}')
        bad += 1
    for g, cu in zip(golden, lines):
        if g != cu:
            bad += 1
            print('- ' + g)
            print('+ ' + cu)
    print(f'golden corpus (PR7 model): {len(lines)} lines, {bad} mismatches')
    return bad == 0


def emit_corpus7(path):
    keep = CORPUS_HEADER3.splitlines()
    lines = generate_corpus_lines7()
    routed_at = next(i for i, l in enumerate(lines) if l.startswith('routed:'))
    routed_comment = [
        '# Routed-placement scenarios (dyadic 4-device/2-node fleet; see',
        '# routed_table/routed_fleet in golden_timelines.rs).',
    ]
    replace_at = next(i for i, l in enumerate(lines)
                      if l.startswith('replace:'))
    replace_comment = [
        '# Live re-placement migration steps: the routed block-placement',
        '# schedules with the block->affinity MigrationPlan overlapped in',
        '# as dependency-free H2D tasks (h<dev> rows; 4096 B/expert over',
        '# an alpha=0.125 beta=1024 H2D link -> 4.125 s per moved expert).',
        '# The pre-existing spans are byte-identical to the routed:block',
        '# entries above (pinned by mirror consistency_checks5).',
    ]
    serve_at = next(i for i, l in enumerate(lines) if l.startswith('serve:'))
    serve_comment = [
        '# Open-loop serving steps: phase_affine_routing batches priced',
        '# on the routed fleet under the block placement. serve:wait1/*',
        '# pins the serving loop\'s per-step traffic-seed advance (seeds',
        '# 97..99, uniform noise 0.25); serve:mixed pins the prefill/',
        '# decode noise split (8 exact prompt tokens + 8 tokens at 0.5).',
    ]
    chaos_at = next(i for i, l in enumerate(lines) if l.startswith('chaos:'))
    chaos_comment = [
        '# Chaos perturbations on the routed block fleet (all rng-free,',
        '# so every span stays dyadic-exact): a persistent 2x straggler',
        '# on device 3, a degraded shared uplink (alpha x2, beta /4 ->',
        '# LinkModel(0.25, 128)), and a device-3 dropout whose failover',
        '# plan (E3 -> device 0, lowest-id tie) overlaps the step as an',
        '# H2D task over the replace-corpus link (4.125 s).',
    ]
    body = (lines[:routed_at] + routed_comment + lines[routed_at:replace_at]
            + replace_comment + lines[replace_at:serve_at]
            + serve_comment + lines[serve_at:chaos_at]
            + chaos_comment + lines[chaos_at:])
    with open(path, 'w') as f:
        f.write('\n'.join(keep) + '\n' + '\n'.join(body) + '\n')
    print(f'emitted {len(lines)} corpus lines to {path}')


# --- PR7 study scenario (the numbers pinned in rust/tests/ ------------
# chaos_suite.rs and quoted in docs/STUDIES.md are minted here) --------

CHAOS_JITTER = 0.10
CHAOS_JITTER_SEED = 77
CHAOS_STRAGGLERS = [(3, 1.5), (17, 2.0)]
CHAOS_FLAP_ALPHA = 8.0
CHAOS_FLAP_BETA = 8.0
CHAOS_FLAP = (4, 2)
CHAOS_DROP_DEVICE = 5
CHAOS_DROP_STEP = 4
C2R_NOISE = 0.15
C2R_COLLAB = 1
C2R_UPLINK_ALPHA = 8.0
C2R_UPLINK_BETA = 16.0


def chaos_scenarios7():
    return [
        ('stragglers', ChaosSpec7(CHAOS_JITTER_SEED, CHAOS_JITTER,
                                  CHAOS_STRAGGLERS, [], None)),
        ('flaky-uplink', ChaosSpec7(0, 0.0, [],
                                    [LinkFault7(None, CHAOS_FLAP_ALPHA,
                                                CHAOS_FLAP_BETA,
                                                CHAOS_FLAP)], None)),
        ('dropout', ChaosSpec7(0, 0.0, [], [],
                               Dropout7(CHAOS_DROP_DEVICE, CHAOS_DROP_STEP))),
    ]


def chaos_cell7(tables, init, strat, slot, policy, spec):
    topo = SCENARIOS['4node-ib']
    return run_chaos_timeline7(
        xl_compute_costs(), topo, REPLACE_STUDY_BYTES, tables, init,
        ('scmoe', 1), strat, policy, REPLACE_STUDY_EXPERT_BYTES,
        REPLACE_STUDY_H2D, 1.0, spec, slot=slot)


def chaos_study7():
    """Full-precision pinned numbers for rust/tests/chaos_suite.rs and
    docs/STUDIES.md (repr() round-trips the exact f64)."""
    tables = replace_drift_tables(0.05, 11)
    placements = [('block', Placement.block(32, 32)),
                  ('affinity', Placement.affinity_packed(tables[0], 32, 8))]
    strategies = [('seq', ('seq',), 0), ('overlap-s2', ('overlap',), 2)]
    policies = [('never',), ('break-even',)]
    for (sname, spec) in [('clean', chaos_clean7(0))] + chaos_scenarios7():
        print(f'== {sname} ==')
        for (pname, init) in placements:
            for (tname, strat, slot) in strategies:
                for pol in policies:
                    st, tot, mig = chaos_cell7(tables, init, strat, slot,
                                               pol, spec)
                    ms = [x[1] for x in st]
                    med = percentile(ms, 50.0)
                    p99 = percentile(ms, 99.0)
                    print('%-8s %-10s %-10s med %r p99 %r amp %r tot %r '
                          'mig %d' % (pname, tname, pol[0], med, p99,
                                      p99 / med, tot, mig))
    print('== c2r ==')
    base_tables = [drifting_node_affine_routing(32, 8, 32, 640, 0, C2R_NOISE,
                                                11 + s) for s in range(16)]
    c2r_tables = [c2r_routing(32, 8, 32, 640, 0, C2R_NOISE, C2R_COLLAB,
                              11 + s) for s in range(16)]
    fault = ChaosSpec7(0, 0.0, [], [LinkFault7(None, C2R_UPLINK_ALPHA,
                                               C2R_UPLINK_BETA, None)], None)
    for (rname, tbl) in [('affine', base_tables), ('c2r', c2r_tables)]:
        init = Placement.affinity_packed(tbl[0], 32, 8)
        for (cname, spec) in [('clean', chaos_clean7(0)),
                              ('degraded', fault)]:
            st, tot, mig = chaos_cell7(tbl, init, ('seq',), 0, ('never',),
                                       spec)
            print('%-7s %-9s tot %r' % (rname, cname, tot))


def consistency_checks7():
    """Reductions the PR7 model must satisfy before its output is
    trusted as a golden or pinned value."""
    topo = Topology(4, 2, LinkModel(0.0625, 1024.0), LinkModel(0.125, 512.0),
                    1.0, None)
    base = ComputeCosts(1.0, 0.75, 0.75, 0.0625, 0.0625, 0.0625, 0.5)
    rt = routed_table3()
    block = Placement.block(4, 4)
    # 1. a zero-magnitude spec leaves every Topology field untouched
    #    (straggler factors of exactly 1.0 and inactive/identity link
    #    faults included), so clean schedules are bit-identical
    zero = ChaosSpec7(9, 0.0, [(2, 1.0)],
                      [LinkFault7(None, 1.0, 1.0, None),
                       LinkFault7(0, 2.0, 2.0, (4, 4))], None)
    assert chaos_is_zero7(chaos_clean7(9))
    assert not chaos_is_zero7(ChaosSpec7(9, 0.0, [], [], Dropout7(0, 0)))
    for s in range(4):
        pt, pni = chaos_perturb7(zero, topo, s)
        assert pt == topo and pni is None
        a = build_spec4(topo_from_routing4(base, topo, rt, block, 64),
                        ('scmoe', 1), ('seq',), 0).run()
        b = build_spec4(topo_from_routing4(base, pt, rt, block, 64, pni),
                        ('scmoe', 1), ('seq',), 0).run()
        assert a == b
    # 2. zero-chaos multi-step timelines ARE run_replace_timeline,
    #    bit-exactly, for every policy
    tables = [drifting_node_affine_routing(4, 2, 4, 4, 0, 0.25, 700 + s)
              for s in range(6)]
    for policy in [('never',), ('every', 2), ('break-even',)]:
        ref = run_replace_timeline(base, topo, 64, tables, block,
                                   ('scmoe', 1), ('seq',), policy, 4096,
                                   REPLACE_H2D_LINK, 1.0)
        got = run_chaos_timeline7(base, topo, 64, tables, block,
                                  ('scmoe', 1), ('seq',), policy, 4096,
                                  REPLACE_H2D_LINK, 1.0, chaos_clean7(3))
        assert got == ref
    # 3. the jitter stream is seed-deterministic, seed-distinct, and
    #    follows the fork(step) contract shared with util/rng.rs
    spec = ChaosSpec7(41, 0.25, [], [], None)
    a1, _ = chaos_perturb7(spec, topo, 2)
    a2, _ = chaos_perturb7(spec, topo, 2)
    assert a1.device_scales == a2.device_scales
    b1, _ = chaos_perturb7(ChaosSpec7(42, 0.25, [], [], None), topo, 2)
    assert a1.device_scales != b1.device_scales
    c1, _ = chaos_perturb7(spec, topo, 3)
    assert a1.device_scales != c1.device_scales
    manual = rng_fork7(Rng(41), 2)
    expect = [1.0 / (1.0 + 0.25 * manual.next_f64()) for _ in range(4)]
    assert a1.device_scales == expect
    # 4. straggler factors compose multiplicatively with jitter scales,
    #    and flap schedules gate faults per step
    s1, _ = chaos_perturb7(ChaosSpec7(41, 0.25, [(3, 2.0)], [], None),
                           topo, 2)
    assert s1.device_scales[:3] == a1.device_scales[:3]
    assert s1.device_scales[3] == a1.device_scales[3] / 2.0
    flap = ChaosSpec7(0, 0.0, [], [LinkFault7(None, 2.0, 4.0, (4, 2))], None)
    for s in range(8):
        pt, _ = chaos_perturb7(flap, topo, s)
        if s % 4 >= 2:
            assert pt.inter == LinkModel(0.25, 128.0)
        else:
            assert pt.inter == topo.inter
    pt, pni = chaos_perturb7(
        ChaosSpec7(0, 0.0, [], [LinkFault7(1, 2.0, 2.0, None)], None),
        topo, 0)
    assert pni == [LinkModel(0.0625, 1024.0), LinkModel(0.125, 512.0)]
    assert pt.inter == topo.inter
    # 5. c2r_routing reduces bit-exactly to the drifting generator at
    #    noise = 0 and stays in-group at any noise (bounded fanout)
    for (regime, seed) in [(0, 3), (1, 11)]:
        a = drifting_node_affine_routing(4, 2, 4, 4, regime, 0.0, seed)
        b = c2r_routing(4, 2, 4, 4, regime, 0.0, 1, seed)
        assert a.routes == b.routes and a.load == b.load
    bounded = c2r_routing(4, 2, 8, 16, 1, 0.6, 2, 5)
    for (t, kk, e, slot, w) in bounded.routes:
        node = (t // 16) // 2
        assert e % 2 == (node + 1) % 2
    # 6. dropout fires the failover unconditionally on its step and no
    #    expert ever lands back on the dead device
    drop = ChaosSpec7(0, 0.0, [], [], Dropout7(3, 1))
    for policy in [('never',), ('break-even',)]:
        st, tot, mig = run_chaos_timeline7(base, topo, 64, tables, block,
                                           ('scmoe', 1), ('seq',), policy,
                                           4096, REPLACE_H2D_LINK, 1.0, drop)
        assert mig >= 1 and st[1][3]  # the forced failover migrated
        assert st[1][4] == 4096  # exactly expert 3's bytes moved
    fo = failover_placement7(block, 3)
    assert [fo.device_of(e) for e in range(4)] == [0, 1, 2, 0]
    skew = failover_placement7(Placement(4, 3, [0, 0, 0, 1]), 0)
    # ascending experts spread over survivors by running load, ties to
    # the lower id: e0 -> d2 (empty), e1 -> d1 (tie), e2 -> d2 (lighter)
    assert [skew.device_of(e) for e in range(4)] == [2, 1, 2, 1]
    print('PR7 consistency checks: OK')


# ======================================================================
# PR 8 model: whole-model simulation — L-layer pipeline-parallel MoE
# timelines with inter-layer affinity placement. Transcribes the
# post-PR8 Rust line-by-line:
#   simtime/engine.rs       -> Resource::D2H ('d2h' engines, d<dev> token)
#   moe/router.rs           -> primary_experts, a2a_bytes_from_sources
#   moe/transition.rs       -> TransitionEstimator8, co_placed8
#   moe/traffic.rs          -> correlated_layer_routing8
#   coordinator/costs.rs    -> sources-aware ChunkSource +
#                              topo_from_routing8 (from_routing_with_sources)
#   coordinator/replace.rs  -> plan_add_transfer_tasks8 /
#                              plan_transfer_time8 (source-side D2H)
#   coordinator/model.rs    -> build_model_sim8, chained_sources8,
#                              model_layer_costs8, run_model_timeline8
# ======================================================================


def d2h(d):
    return ("d2h", d)


def primary_experts8(rt):
    """RoutingTable::primary_experts — each token's first kept k-slot-0
    expert, None for tokens whose primary route dropped."""
    primary = [None] * rt.n_tokens
    for (t, kk, e, slot, w) in rt.routes:
        if kk == 0 and primary[t] is None:
            primary[t] = e
    return primary


def a2a_bytes_from_sources8(rt, sources, placement, token_bytes):
    """RoutingTable::a2a_bytes_from_sources — the dispatch byte matrix
    priced from an explicit per-token source-device map instead of the
    even index-order home split."""
    assert placement.n_experts == rt.n_experts
    assert len(sources) == rt.n_tokens
    n_devices = placement.n_devices
    mat = [0] * (n_devices * n_devices)
    for (t, kk, e, slot, w) in rt.routes:
        src = sources[t]
        assert src < n_devices
        dst = placement.device_of(e)
        mat[src * n_devices + dst] += token_bytes
    return mat


def topo_from_routing8(base, topo, rt, placement, token_bytes, sources=None,
                       node_intra=None):
    """TopoCosts::from_routing_with_sources + ExpertLoad — identical to
    topo_from_routing4 except the dispatch matrix (and the recorded
    ChunkSource) may come from explicit per-token sources."""
    n = topo.n_devices
    links = topo_intra_links(topo, node_intra)
    if sources is None:
        disp = rt.a2a_bytes_placed(placement, token_bytes)
    else:
        disp = a2a_bytes_from_sources8(rt, sources, placement, token_bytes)
    comb = transpose(disp, n)
    pdi, pdx, pdia, pdxa = a2a_decompose_pn3(
        disp, n, topo.devices_per_node, links, topo.inter)
    pci, pcx, pcia, pcxa = a2a_decompose_pn3(
        comb, n, topo.devices_per_node, links, topo.inter)
    kf = float(max(rt.k, 1))
    scale = lambda v: [x / kf for x in v]
    td, ad = a2a_time_split_pn(disp, n, topo.devices_per_node, links,
                               topo.inter)
    tcm, acm = a2a_time_split_pn(comb, n, topo.devices_per_node, links,
                                 topo.inter)
    if tcm > td:
        flat, flat_a = tcm / kf, acm / kf
    else:
        flat, flat_a = td / kf, ad / kf
    per_device = []
    for d in range(n):
        s = topo.device_compute_scale(d)
        per_device.append(BlockCosts3(base.attn / s, base.mlp / s, base.se / s,
                                      base.gate / s, base.encode / s,
                                      base.decode / s, base.expert_k1 / s,
                                      flat, flat_a))
    tc3 = TopoCosts3(per_device, scale(pdi), scale(pdx),
                     topo.devices_per_node,
                     intra_c=scale(pci), inter_c=scale(pcx),
                     intra_a=scale(pdia), inter_a=scale(pdxa),
                     intra_ca=scale(pcia), inter_ca=scale(pcxa),
                     chunk_source=ChunkSource(rt, placement, token_bytes,
                                              links, topo.inter, sources))
    return TopoCosts4(tc3, ExpertLoad.from_routing(rt, placement))


def plan_add_transfer_tasks8(plan, sim, h2d_link, d2h_link=None,
                             device_offset=0):
    """MigrationPlan::add_transfer_tasks — with a D2H link each move
    first reads out on the source device's d2h engine and the H2D write
    depends on it; without one the legacy dependency-free H2D tasks are
    emitted bit-exactly. device_offset lands a layer's migration on its
    pipeline stage's engines."""
    out = []
    for (e, f, t, b) in plan.moves:
        deps = []
        if d2h_link is not None:
            deps = [sim.add(f"D2H-E{e}", d2h(f + device_offset),
                            transfer_time(d2h_link, b), [])]
        out.append(sim.add(f"H2D-E{e}", h2d(t + device_offset),
                           transfer_time(h2d_link, b), deps))
    return out


def plan_transfer_time8(plan, h2d_link, d2h_link=None):
    """MigrationPlan::transfer_time — analytic per-destination
    serialization without D2H; a scratch DES of exactly the transfer
    tasks with it (source-engine stalls are simulated, not summed)."""
    if d2h_link is None:
        return plan.time(h2d_link)
    sim = Sim()
    plan_add_transfer_tasks8(plan, sim, h2d_link, d2h_link, 0)
    return sim.makespan()


def correlated_layer_routing8(prev, n_experts, stride, noise, seed):
    """moe::traffic::correlated_layer_routing — ExFlow-style inter-layer
    correlated k=1 routing: with probability 1-noise a token routes to
    (prev_primary + stride) % n_experts; otherwise (or when its primary
    dropped) it scatters uniformly. One next_f64 per token plus one
    below() on the scatter branches."""
    assert prev.n_experts == n_experts
    n_tokens = prev.n_tokens
    assert n_tokens > 0
    primary = primary_experts8(prev)
    rng = Rng(seed)
    indices = []
    weights = [1.0] * n_tokens
    for t in range(n_tokens):
        if rng.next_f64() < noise:
            e = rng.below(n_experts)
        elif primary[t] is not None:
            e = (primary[t] + stride) % n_experts
        else:
            e = rng.below(n_experts)
        indices.append(e)
    return RoutingTable(indices, weights, n_tokens, 1, n_experts, n_tokens)


class TransitionEstimator8:
    """moe::TransitionEstimator — discounted [prev_expert, next_expert]
    primary-route transition counts over adjacent-layer table pairs."""

    def __init__(self, n_experts, decay):
        assert n_experts > 0
        assert 0.0 < decay <= 1.0
        self.n_experts = n_experts
        self.decay = decay
        self.counts = [0.0] * (n_experts * n_experts)
        self.steps = 0

    def observe(self, prev, next_):
        assert prev.n_experts == self.n_experts
        assert next_.n_experts == self.n_experts
        assert prev.n_tokens == next_.n_tokens
        pe = primary_experts8(prev)
        ne = primary_experts8(next_)
        obs = [0] * (self.n_experts * self.n_experts)
        for t in range(prev.n_tokens):
            if pe[t] is not None and ne[t] is not None:
                obs[pe[t] * self.n_experts + ne[t]] += 1
        for i in range(len(self.counts)):
            self.counts[i] = self.decay * self.counts[i] + float(obs[i])
        self.steps += 1

    def count(self, e, f):
        return self.counts[e * self.n_experts + f]


def co_placed8(aff, trans, prev, n_devices, devices_per_node):
    """moe::co_placed — ExFlow-style cross-layer co-placement: each
    next-layer expert's affinity row is augmented with the transition
    counts arriving from every previous-layer expert's resident node,
    then fed to the same greedy packer. Zero transition counts reduce
    bit-exactly to affinity_packed_measured on aff alone."""
    assert devices_per_node > 0 and n_devices % devices_per_node == 0
    n_nodes = n_devices // devices_per_node
    n_experts = trans.n_experts
    assert len(aff) == n_experts * n_nodes
    assert prev.n_experts == n_experts
    combined = list(aff)
    for e in range(n_experts):
        node = prev.device_of(e) // devices_per_node
        for f in range(n_experts):
            combined[f * n_nodes + node] += trans.count(e, f)
    return affinity_packed_measured(combined, n_experts, n_devices,
                                    devices_per_node)


def chained_sources8(prev, prev_placement):
    """coordinator::model::chained_sources — where each token's
    activations sit when the next layer dispatches: the device owning
    its previous primary expert, or its home device if that dropped."""
    n_devices = prev_placement.n_devices
    tokens_per_device = -(-prev.n_tokens // n_devices)
    out = []
    for t, p in enumerate(primary_experts8(prev)):
        if p is not None:
            out.append(prev_placement.device_of(p))
        else:
            out.append(min(t // tokens_per_device, n_devices - 1))
    return out


def model_layer_costs8(base, topo, token_bytes, layer_tables, placements,
                       microbatches):
    """coordinator::model::model_layer_costs — costs[l][m]: layer 0 from
    home sources, layer l >= 1 from the chained sources its
    predecessor's placement implies; parts keep parent token ids so one
    source vector per layer serves every microbatch."""
    assert len(layer_tables) == len(placements)
    out = []
    for l, rt in enumerate(layer_tables):
        if l == 0:
            sources = None
        else:
            sources = chained_sources8(layer_tables[l - 1],
                                       placements[l - 1])
        placement = placements[l]
        cost_of = lambda part: topo_from_routing8(base, topo, part,
                                                  placement, token_bytes,
                                                  sources)
        if microbatches == 1:
            row = [cost_of(rt)]
        else:
            row = [cost_of(p) for p in chunk_rt(rt, microbatches)]
        out.append(row)
    return out


# PipelineSchedule labels (shared with the Rust study tables)
LAYERSEQ = 'layerseq'
GPIPE = 'gpipe'
ONEFONEB = '1f1b'


def remap_res8(res, stage, devices_per_stage, nodes_per_stage):
    """coordinator::model::remap_resource — device engines shift by
    stage * devices_per_stage, links by stage * nodes_per_stage."""
    kind = res[0]
    if kind in ('compute', 'comm', 'h2d', 'd2h'):
        return (kind, res[1] + stage * devices_per_stage)
    if kind == 'link':
        return (kind, res[1] + stage * nodes_per_stage)
    return res


def build_model_sim8(layers, stages, microbatches, schedule, costs,
                     devices_per_stage, nodes_per_stage):
    """coordinator::model::build_model_sim — layers is a list of
    (kind, strat, slot, pipelining) spec tuples, costs[l][m] prices
    layer l over microbatch m. Each pair graph is embedded with
    resources remapped onto its stage, in-graph deps offset, roots
    chained behind the schedule's required joins, and capped with a
    zero-duration Join-L{l}M{m} task. Insertion order is layer-major
    for layerseq, microbatch-major for the pipelined schedules (1F1B's
    window dep needs mb-S's last join to already exist)."""
    n_layers = len(layers)
    assert n_layers >= 1 and stages >= 1 and microbatches >= 1
    assert n_layers % stages == 0
    lps = n_layers // stages
    sim = Sim()
    joins = [[0] * microbatches for _ in range(n_layers)]

    def embed(l, mb):
        if schedule == LAYERSEQ:
            roots = list(joins[l - 1]) if l > 0 else []
        else:
            roots = [joins[l - 1][mb]] if l > 0 else []
        if schedule == ONEFONEB and l == 0 and mb >= stages:
            roots.append(joins[n_layers - 1][mb - stages])
        stage = l // lps
        kind, strat, slot, pipelining = layers[l]
        pair = build_spec4(costs[l][mb], kind, strat, slot, pipelining)
        off = len(sim.tasks)
        count = len(pair.tasks)
        for (label, res, dur, deps) in pair.tasks:
            nd = list(roots) if not deps else [d + off for d in deps]
            sim.add(label, remap_res8(res, stage, devices_per_stage,
                                      nodes_per_stage), dur, nd)
        joins[l][mb] = sim.add(f"Join-L{l}M{mb}", FREE, 0.0,
                               list(range(off, off + count)))

    if schedule == LAYERSEQ:
        for l in range(n_layers):
            for mb in range(microbatches):
                embed(l, mb)
    else:
        for mb in range(microbatches):
            for l in range(n_layers):
                embed(l, mb)
    return sim, joins


def run_model_timeline8(base, topo, token_bytes, tables, initial, layers,
                        stages, microbatches, schedule, policy,
                        bytes_per_expert, h2d_link, d2h_link, decay, mode):
    """coordinator::model::run_model_timeline — tables[step][layer],
    one placement per layer; mode = 'per-layer' | 'cross-layer'.
    Returns (steps, total, migrations, placements) with steps =
    (step, makespan, base_makespan, migrated, bytes, mig_time)."""
    n_layers = len(layers)
    assert tables
    assert len(initial) == n_layers
    n_nodes = topo.n_devices // topo.devices_per_node
    ests = [AffinityEstimator(p.n_experts, n_nodes, decay) for p in initial]
    trans = [TransitionEstimator8(initial[l].n_experts, decay)
             for l in range(n_layers - 1)]
    placements = list(initial)
    steps = []
    total = 0.0
    migrations = 0
    n_steps = len(tables)

    def candidates_of():
        if mode == 'per-layer':
            return [e.packed(topo.n_devices, topo.devices_per_node)
                    for e in ests]
        out = [ests[0].packed(topo.n_devices, topo.devices_per_node)]
        for l in range(1, n_layers):
            out.append(co_placed8(ests[l].counts, trans[l - 1], out[l - 1],
                                  topo.n_devices, topo.devices_per_node))
        return out

    for s, layer_tables in enumerate(tables):
        def model_sim(pl):
            costs = model_layer_costs8(base, topo, token_bytes,
                                       layer_tables, pl, microbatches)
            return build_model_sim8(layers, stages, microbatches, schedule,
                                    costs, topo.n_devices, n_nodes)[0]
        sim = model_sim(placements)
        base_makespan = sim.makespan()
        for l, rt in enumerate(layer_tables):
            ests[l].observe(rt, topo.n_devices, topo.devices_per_node)
        for l in range(n_layers - 1):
            trans[l].observe(layer_tables[l], layer_tables[l + 1])
        remaining = n_steps - s - 1
        migrated = False
        mig_bytes = 0
        mig_time = 0.0
        if remaining > 0 and policy[0] != 'never':
            candidates = candidates_of()
            plans = [MigrationPlan.between(placements[l], candidates[l],
                                           bytes_per_expert)
                     for l in range(n_layers)]
            if any(not p.is_empty() for p in plans):
                # layers migrate concurrently on their own stages'
                # engines: the model-level transfer time is the slowest
                # layer plan's
                mig = 0.0
                for p in plans:
                    mig = max(mig, plan_transfer_time8(p, h2d_link,
                                                       d2h_link))
                overhead = max(0.0, mig - base_makespan)
                if policy[0] == 'break-even':
                    saving = base_makespan - model_sim(candidates).makespan()
                else:
                    saving = 0.0
                if should_migrate(policy, s, remaining, saving, overhead):
                    for l, p in enumerate(plans):
                        if not p.is_empty():
                            plan_add_transfer_tasks8(
                                p, sim, h2d_link, d2h_link,
                                (l // (n_layers // stages)) * topo.n_devices)
                    migrated = True
                    mig_bytes = sum(p.total_bytes() for p in plans)
                    mig_time = mig
                    placements = candidates
                    migrations += 1
        makespan = sim.makespan() if migrated else base_makespan
        total += makespan
        steps.append((s, makespan, base_makespan, migrated, mig_bytes,
                      mig_time))
    return steps, total, migrations, placements


# --- PR8 golden corpus additions --------------------------------------

MODEL_SEQ_SPEC = (('scmoe', 1), ('seq',), 0, STAGED)
MODEL_D2H_LINK = LinkModel(0.0625, 2048.0)


def generate_model_lines8():
    """Whole-model goldens on the dyadic routed fleet: layer 0 is the
    routed corpus table, layer 1 its +1-stride successor (chained
    sources under the block placement), all dyadic-exact. The final line
    pins source-side D2H pricing: the replace-corpus block->affinity
    plan with each H2D write chained behind its d2h read-out
    (0.0625 + 4096/2048 = 2.0625 s per moved expert on d<dev>)."""
    rt0 = routed_table3()
    idx1 = [(e + 1) % 4
            for e in [0, 2, 0, 2, 2, 0, 0, 2, 1, 3, 3, 1, 3, 1, 3, 3]]
    rt1 = RoutingTable(idx1, [1.0] * 16, 16, 1, 4, 16)
    topo = Topology(4, 2, LinkModel(0.0625, 1024.0), LinkModel(0.125, 512.0),
                    1.0, None)
    base = ComputeCosts(1.0, 0.75, 0.75, 0.0625, 0.0625, 0.0625, 0.5)
    block = Placement.block(4, 4)
    lines = []

    def model_line(name, n_layers, stages, microbatches, schedule):
        tabs = [rt0, rt1][:n_layers]
        pls = [block] * n_layers
        costs = model_layer_costs8(base, topo, 64, tabs, pls, microbatches)
        sim, _ = build_model_sim8([MODEL_SEQ_SPEC] * n_layers, stages,
                                  microbatches, schedule, costs, 4, 2)
        return render_line(name, sim)

    lines.append(model_line('model:L1/seq-m1', 1, 1, 1, LAYERSEQ))
    lines.append(model_line('model:L2/seq-m1', 2, 1, 1, LAYERSEQ))
    lines.append(model_line('model:L2/gpipe-m2', 2, 1, 2, GPIPE))
    lines.append(model_line('model:L2/1f1b-m2', 2, 1, 2, ONEFONEB))
    lines.append(model_line('model:L2S2/gpipe-m2', 2, 2, 2, GPIPE))
    lines.append(model_line('model:L2S2/layerseq-m2', 2, 2, 2, LAYERSEQ))
    affinity = Placement.affinity_packed(rt0, 4, 2)
    plan = MigrationPlan.between(block, affinity, REPLACE_BYTES_PER_EXPERT)
    sim = build_spec4(routed_fleet4(rt0, block), ('scmoe', 1), ('seq',), 0)
    plan_add_transfer_tasks8(plan, sim, REPLACE_H2D_LINK, MODEL_D2H_LINK, 0)
    lines.append(render_line('model:d2h-migration/seq', sim))
    return lines


def generate_corpus_lines8():
    return generate_corpus_lines7() + generate_model_lines8()


def validate_corpus8():
    golden_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                               '..', '..', 'rust', 'tests', 'golden',
                               'timelines.txt')
    golden = [l for l in open(golden_path).read().splitlines()
              if l.strip() and not l.startswith('#')]
    lines = generate_corpus_lines8()
    bad = 0
    if len(golden) != len(lines):
        print(f'line-count mismatch: golden {len(golden)} vs mirror {len(lines)}')
        bad += 1
    for g, cu in zip(golden, lines):
        if g != cu:
            bad += 1
            print('- ' + g)
            print('+ ' + cu)
    print(f'golden corpus (PR8 model): {len(lines)} lines, {bad} mismatches')
    return bad == 0


def emit_corpus8(path):
    keep = CORPUS_HEADER3.splitlines()
    lines = generate_corpus_lines8()
    routed_at = next(i for i, l in enumerate(lines) if l.startswith('routed:'))
    routed_comment = [
        '# Routed-placement scenarios (dyadic 4-device/2-node fleet; see',
        '# routed_table/routed_fleet in golden_timelines.rs).',
    ]
    replace_at = next(i for i, l in enumerate(lines)
                      if l.startswith('replace:'))
    replace_comment = [
        '# Live re-placement migration steps: the routed block-placement',
        '# schedules with the block->affinity MigrationPlan overlapped in',
        '# as dependency-free H2D tasks (h<dev> rows; 4096 B/expert over',
        '# an alpha=0.125 beta=1024 H2D link -> 4.125 s per moved expert).',
        '# The pre-existing spans are byte-identical to the routed:block',
        '# entries above (pinned by mirror consistency_checks5).',
    ]
    serve_at = next(i for i, l in enumerate(lines) if l.startswith('serve:'))
    serve_comment = [
        '# Open-loop serving steps: phase_affine_routing batches priced',
        '# on the routed fleet under the block placement. serve:wait1/*',
        '# pins the serving loop\'s per-step traffic-seed advance (seeds',
        '# 97..99, uniform noise 0.25); serve:mixed pins the prefill/',
        '# decode noise split (8 exact prompt tokens + 8 tokens at 0.5).',
    ]
    chaos_at = next(i for i, l in enumerate(lines) if l.startswith('chaos:'))
    chaos_comment = [
        '# Chaos perturbations on the routed block fleet (all rng-free,',
        '# so every span stays dyadic-exact): a persistent 2x straggler',
        '# on device 3, a degraded shared uplink (alpha x2, beta /4 ->',
        '# LinkModel(0.25, 128)), and a device-3 dropout whose failover',
        '# plan (E3 -> device 0, lowest-id tie) overlaps the step as an',
        '# H2D task over the replace-corpus link (4.125 s).',
    ]
    model_at = next(i for i, l in enumerate(lines) if l.startswith('model:'))
    model_comment = [
        '# Whole-model L-layer pipeline timelines (build_model_sim):',
        '# layer 0 is the routed corpus table, layer 1 its +1-stride',
        '# successor priced from chained sources under the block',
        '# placement. L2S2 lines put layer 1 on stage 1\'s engines',
        '# (c4..c7, m4..m7, l2..l3). model:d2h-migration chains each',
        '# H2D write behind its source-side D2H read-out (d<dev> rows;',
        '# 4096 B/expert over alpha=0.0625 beta=2048 -> 2.0625 s).',
    ]
    body = (lines[:routed_at] + routed_comment + lines[routed_at:replace_at]
            + replace_comment + lines[replace_at:serve_at]
            + serve_comment + lines[serve_at:chaos_at]
            + chaos_comment + lines[chaos_at:model_at]
            + model_comment + lines[model_at:])
    with open(path, 'w') as f:
        f.write('\n'.join(keep) + '\n' + '\n'.join(body) + '\n')
    print(f'emitted {len(lines)} corpus lines to {path}')


# --- PR8 study scenario (the numbers pinned in rust/tests/ ------------
# model_timeline.rs and quoted in docs/STUDIES.md are minted here) -----

MODEL_NOISE = 1.0
MODEL_CORR_NOISE = 0.05
MODEL_STRIDE = 5
MODEL_LAYERS = 4
MODEL_STAGES = 2
MODEL_STEPS = 4
MODEL_SEED = 211
MODEL_STUDY_D2H = LinkModel(10e-6, 32e9)


def model_tables8(n_steps, n_layers, seed0):
    """One row of per-layer tables per step: layer 0 fully uniform
    (noise 1.0 -> a token's home node predicts nothing, so the
    home-anchored affinity counts are flat to sampling noise at every
    depth), while deeper layers follow the +MODEL_STRIDE expert
    transition almost deterministically (noise 0.05). A deterministic
    expert->expert permutation propagates any home tilt perfectly, so
    with home-affine layer-0 traffic per-layer packing co-places chains
    by accident; only with the home signal gone does the measured
    inter-layer transition carry information the per-layer packer cannot
    see — exactly the correlation ExFlow exploits."""
    out = []
    for s in range(n_steps):
        row = [phase_affine_routing(32, 8, 32,
                                    32 * REPLACE_STUDY_TOKENS, 0, 0,
                                    MODEL_NOISE, MODEL_NOISE,
                                    seed0 + 100 * s)]
        for l in range(1, n_layers):
            row.append(correlated_layer_routing8(row[-1], 32, MODEL_STRIDE,
                                                 MODEL_CORR_NOISE,
                                                 seed0 + 100 * s + l))
        out.append(row)
    return out


def model_grid_placements8(tables0):
    """Warm-started per-layer and cross-layer placements from the step-0
    tables (counting estimators, one observation each) — the static
    endpoints of the report grid."""
    n_layers = len(tables0)
    ests = [AffinityEstimator(32, 4, 1.0) for _ in range(n_layers)]
    for l, rt in enumerate(tables0):
        ests[l].observe(rt, 32, 8)
    trans = [TransitionEstimator8(32, 1.0) for _ in range(n_layers - 1)]
    for l in range(n_layers - 1):
        trans[l].observe(tables0[l], tables0[l + 1])
    per = [e.packed(32, 8) for e in ests]
    cross = [ests[0].packed(32, 8)]
    for l in range(1, n_layers):
        cross.append(co_placed8(ests[l].counts, trans[l - 1], cross[l - 1],
                                32, 8))
    return per, cross


def model_cell8(tables, initial, microbatches, schedule, policy, mode,
                d2h_link=None):
    topo = SCENARIOS['4node-ib']
    return run_model_timeline8(
        xl_compute_costs(), topo, REPLACE_STUDY_BYTES, tables, initial,
        [MODEL_SEQ_SPEC] * MODEL_LAYERS, MODEL_STAGES, microbatches,
        schedule, policy, REPLACE_STUDY_EXPERT_BYTES, REPLACE_STUDY_H2D,
        d2h_link, 1.0, mode)


def model_study8():
    """Full-precision pinned numbers for rust/tests/model_timeline.rs
    and docs/STUDIES.md (repr() round-trips the exact f64)."""
    tables = model_tables8(MODEL_STEPS, MODEL_LAYERS, MODEL_SEED)
    per, cross = model_grid_placements8(tables[0])
    blk = [Placement.block(32, 32)] * MODEL_LAYERS
    placements = [('block', blk), ('per-layer', per), ('cross-layer', cross)]
    for m in [1, MODEL_STAGES * 2]:
        for schedule in [LAYERSEQ, GPIPE, ONEFONEB]:
            for (pname, init) in placements:
                st, tot, mig, _ = model_cell8(tables, init, m, schedule,
                                              ('never',), 'per-layer')
                print('m%-2d %-9s %-11s tot %r' % (m, schedule, pname, tot))
    # live re-placement: block start, break-even policy, cross-layer
    # candidates, D2H-priced transfers
    st, tot, mig, _ = model_cell8(tables, blk, MODEL_STAGES * 2, GPIPE,
                                  ('break-even',), 'cross-layer',
                                  MODEL_STUDY_D2H)
    print('live m%d gpipe block->cross break-even tot %r mig %d'
          % (MODEL_STAGES * 2, tot, mig))
    per_steps = [x[1] for x in st]
    print('live steps %s' % ' '.join(repr(x) for x in per_steps))


# --- PR8 heterogeneous serving study ----------------------------------

HETERO_SHORT_PREFILL = 1024
HETERO_SHORT_DECODE = 2
HETERO_LONG_PREFILL = 4096
HETERO_LONG_DECODE = 8


def hetero_requests8(rate):
    """serve::arrivals::trace_arrivals input: the Poisson instants of
    the homogeneous study remapped to alternating short (1024 prompt /
    2 decode steps) and long (4096 / 8) request shapes by index."""
    base = poisson_arrivals(SERVE_REQUESTS, rate, SERVE_TICK,
                            SERVE_PREFILL_TOKENS, SERVE_DECODE_STEPS,
                            SERVE_SEED)
    out = []
    for i, (arr, _pf, _ds) in enumerate(base):
        if i % 2 == 0:
            out.append((arr, HETERO_SHORT_PREFILL, HETERO_SHORT_DECODE))
        else:
            out.append((arr, HETERO_LONG_PREFILL, HETERO_LONG_DECODE))
    return out


def serve_hetero_cell8(rate, strat, batching, policy):
    topo = SCENARIOS['4node-ib']
    base = xl_compute_costs()
    slot = SERVE_OVERLAP_SLOT if strat[0] == 'overlap' else 0
    return run_serve(base, topo, hetero_requests8(rate),
                     Placement.block(32, 32), ('scmoe', 1), strat, batching,
                     policy, 1.0, REPLACE_STUDY_EXPERT_BYTES,
                     REPLACE_STUDY_H2D, SERVE_TOKEN_BYTES,
                     SERVE_DECODE_TOKENS, 32, 0, None, SERVE_PREFILL_NOISE,
                     SERVE_DECODE_NOISE, SERVE_TRAFFIC_SEED, slot)


def serve_hetero_study8():
    """Full-precision pinned numbers for the mixed-shape serving column
    (rust/tests/serve_loop.rs / docs/STUDIES.md)."""
    budget = ('budget', SERVE_BUDGET)
    for strat in [('seq',), ('overlap',)]:
        for policy in [('never',), ('break-even',)]:
            for rate in SERVE_LOADS:
                steps, lat, busy, total, mig, _ = serve_hetero_cell8(
                    rate, strat, budget, policy)
                p50 = percentile(lat, 50.0)
                p99 = percentile(lat, 99.0)
                print('hetero load %5.0f %-7s %-10s steps %3d migr %2d' %
                      (rate, strat[0], policy[0], len(steps), mig))
                print('  p50 %r p99 %r req/s %r goodput %r' %
                      (p50, p99, len(lat) / total,
                       sum(1 for l in lat if l <= SERVE_SLO) / total))


def consistency_checks8():
    """Reductions the PR8 model must satisfy before its output is
    trusted as a golden or pinned value."""
    topo = Topology(4, 2, LinkModel(0.0625, 1024.0), LinkModel(0.125, 512.0),
                    1.0, None)
    base = ComputeCosts(1.0, 0.75, 0.75, 0.0625, 0.0625, 0.0625, 0.5)
    rt = routed_table3()
    block = Placement.block(4, 4)
    # 1. sources-aware routed costs without sources == topo_from_routing4
    #    bit-exactly, unchunked and token-true chunked
    for strat in [('seq',), ('pipe', 2)]:
        a = render_line('x', build_spec4(
            topo_from_routing4(base, topo, rt, block, 64),
            ('scmoe', 1), strat, 0))
        b = render_line('x', build_spec4(
            topo_from_routing8(base, topo, rt, block, 64),
            ('scmoe', 1), strat, 0))
        assert a == b, ('sources=None drifted', strat)
    # 2. the explicit home-split source map reproduces the even split
    tpd = -(-rt.n_tokens // 4)
    home = [min(t // tpd, 3) for t in range(rt.n_tokens)]
    for strat in [('seq',), ('pipe', 2)]:
        a = render_line('x', build_spec4(
            topo_from_routing8(base, topo, rt, block, 64),
            ('scmoe', 1), strat, 0))
        b = render_line('x', build_spec4(
            topo_from_routing8(base, topo, rt, block, 64, home),
            ('scmoe', 1), strat, 0))
        assert a == b, ('home sources drifted', strat)
    # 3. L=S=M=1 build_model_sim8 is the pair schedule plus one join
    costs = model_layer_costs8(base, topo, 64, [rt], [block], 1)
    msim, joins = build_model_sim8([MODEL_SEQ_SPEC], 1, 1, LAYERSEQ, costs,
                                   4, 2)
    pair = build_spec4(costs[0][0], ('scmoe', 1), ('seq',), 0)
    assert len(msim.tasks) == len(pair.tasks) + 1
    assert joins == [[len(pair.tasks)]]
    assert msim.run()[:len(pair.tasks)] == pair.run()
    assert msim.makespan() == pair.makespan()
    # 4. the L=1 model timeline IS run_replace_timeline, field for field,
    #    for every policy (final placements included)
    tables = [drifting_node_affine_routing(4, 2, 4, 4, 0, 0.25, 800 + s)
              for s in range(5)]
    for policy in [('never',), ('every', 2), ('break-even',)]:
        ref = run_replace_timeline(base, topo, 64, tables, block,
                                   ('scmoe', 1), ('seq',), policy, 4096,
                                   REPLACE_H2D_LINK, 1.0)
        st, tot, mig, pls = run_model_timeline8(
            base, topo, 64, [[t] for t in tables], [block],
            [MODEL_SEQ_SPEC], 1, 1, LAYERSEQ, policy, 4096,
            REPLACE_H2D_LINK, None, 1.0, 'cross-layer')
        assert (st, tot, mig) == ref, policy
        if policy[0] != 'never':
            final = ref_final_placement8(base, topo, tables, block, policy)
            assert pls[0].map == final.map
    # 5. zero transition counts: co_placed8 == affinity_packed_measured
    est = AffinityEstimator(4, 2, 1.0)
    est.observe(rt, 4, 2)
    tr0 = TransitionEstimator8(4, 1.0)
    a = co_placed8(est.counts, tr0, block, 4, 2)
    b = affinity_packed_measured(est.counts, 4, 4, 2)
    assert a.map == b.map
    # 6. an infinite-bandwidth D2H link prices every timeline bit-exactly
    #    like no D2H link at all (zero-duration read-outs stall nothing)
    free_d2h = LinkModel(0.0, float('inf'))
    for policy in [('every', 2), ('break-even',)]:
        a = run_model_timeline8(base, topo, 64, [[t] for t in tables],
                                [block], [MODEL_SEQ_SPEC], 1, 1, LAYERSEQ,
                                policy, 4096, REPLACE_H2D_LINK, None, 1.0,
                                'per-layer')
        b = run_model_timeline8(base, topo, 64, [[t] for t in tables],
                                [block], [MODEL_SEQ_SPEC], 1, 1, LAYERSEQ,
                                policy, 4096, REPLACE_H2D_LINK, free_d2h,
                                1.0, 'per-layer')
        assert a[:3] == b[:3], policy
    # 7. gpipe == layerseq at one microbatch (identical root structure)
    idx1 = [(e + 1) % 4
            for e in [0, 2, 0, 2, 2, 0, 0, 2, 1, 3, 3, 1, 3, 1, 3, 3]]
    rt1 = RoutingTable(idx1, [1.0] * 16, 16, 1, 4, 16)
    costs2 = model_layer_costs8(base, topo, 64, [rt, rt1], [block, block], 1)
    g = build_model_sim8([MODEL_SEQ_SPEC] * 2, 1, 1, GPIPE, costs2, 4, 2)[0]
    s = build_model_sim8([MODEL_SEQ_SPEC] * 2, 1, 1, LAYERSEQ, costs2,
                         4, 2)[0]
    assert g.run() == s.run()
    print('PR8 consistency checks: OK')


def ref_final_placement8(base, topo, tables, initial, policy):
    """Replays run_replace_timeline's placement updates (the PR5 helper
    returns only (steps, total, migrations))."""
    n_nodes = topo.n_devices // topo.devices_per_node
    est = AffinityEstimator(initial.n_experts, n_nodes, 1.0)
    placement = initial
    n_steps = len(tables)
    for s, rt in enumerate(tables):
        costs = topo_from_routing4(base, topo, rt, placement, 64)
        base_makespan = build_spec4(costs, ('scmoe', 1), ('seq',),
                                    0).makespan()
        est.observe(rt, topo.n_devices, topo.devices_per_node)
        remaining = n_steps - s - 1
        if remaining > 0 and policy[0] != 'never':
            candidate = est.packed(topo.n_devices, topo.devices_per_node)
            plan = MigrationPlan.between(placement, candidate, 4096)
            if not plan.is_empty():
                mig = plan.time(REPLACE_H2D_LINK)
                overhead = max(0.0, mig - base_makespan)
                if policy[0] == 'break-even':
                    cand_costs = topo_from_routing4(base, topo, rt,
                                                    candidate, 64)
                    saving = base_makespan - build_spec4(
                        cand_costs, ('scmoe', 1), ('seq',), 0).makespan()
                else:
                    saving = 0.0
                if should_migrate(policy, s, remaining, saving, overhead):
                    placement = candidate
    return placement


# ======================================================================
# PR 9 model: the timeline analysis layer. Transcribes the post-PR9 Rust
# line-by-line:
#   simtime/engine.rs (run_traced) -> run_traced9
#   analyze/critpath.rs            -> critical_path9 / slack9 / attribute9
#   analyze/overlap.rs             -> comm_overlap9 / utilization9 /
#                                     stage_bubbles9
#   analyze/export.rs + util/json  -> chrome_trace9 / json9
# Both engines key their ready heaps by (ready_at, task id), so pop order
# — and therefore last_on and every realized blocking edge — matches the
# Rust engine exactly, and the analytics below are bit-identical.
# ======================================================================

# Rust Resource derives Ord over declaration order:
# Compute, Comm, Link, H2D, D2H, Free.
RES_RANK9 = {'compute': 0, 'comm': 1, 'link': 2, 'h2d': 3, 'd2h': 4,
             'free': 5}


def res_key9(r):
    return (RES_RANK9[r[0]], r[1] if len(r) > 1 else 0)


def run_traced9(sim):
    """simtime::engine::Sim::run_traced — spans plus, per task, the
    realized blocking predecessor: (pred, 'res') when the exclusive
    resource freed after the deps finished, (pred, 'dep') to the
    latest-finishing dep otherwise (first on ties), None when the task
    started unconstrained at t = 0."""
    n = len(sim.tasks)
    remaining = [len(t[3]) for t in sim.tasks]
    dependents = [[] for _ in range(n)]
    for i, t in enumerate(sim.tasks):
        for d in t[3]:
            dependents[d].append(i)
    heap = []
    ready_at = [0.0] * n
    for i, t in enumerate(sim.tasks):
        if not t[3]:
            heapq.heappush(heap, (0.0, i))
    free = {}
    last_on = {}
    spans = [None] * n
    blockers = [None] * n
    done = 0

    def latest_dep(i):
        best = None
        for d in sim.tasks[i][3]:
            end = spans[d][4]
            if best is None or end > best[1]:
                best = (d, end)
        return None if best is None else (best[0], 'dep')

    while heap:
        _, i = heapq.heappop(heap)
        label, res, dur, deps = sim.tasks[i]
        if res == FREE:
            start, blk = ready_at[i], latest_dep(i)
        else:
            f = free.get(res, 0.0)
            if f > ready_at[i]:
                start, blk = f, (last_on[res], 'res')
            else:
                start, blk = ready_at[i], latest_dep(i)
        end = start + dur
        if res != FREE:
            free[res] = end
            last_on[res] = i
        spans[i] = (i, label, res, start, end)
        blockers[i] = blk
        done += 1
        for dep in dependents[i]:
            ready_at[dep] = max(ready_at[dep], end)
            remaining[dep] -= 1
            if remaining[dep] == 0:
                heapq.heappush(heap, (ready_at[dep], dep))
    assert done == n, 'cycle'
    return spans, blockers


def critical_path9(spans, blockers):
    """analyze::critpath::critical_path — walk blockers back from the
    latest-finishing span (lowest id on ties)."""
    if not spans:
        return []
    sink = 0
    for sp in spans:
        if sp[4] > spans[sink][4]:
            sink = sp[0]
    path = [sink]
    while blockers[path[-1]] is not None:
        path.append(blockers[path[-1]][0])
    path.reverse()
    return path


def slack9(sim, spans):
    """analyze::critpath::slack — CPM over dep edges plus the realized
    per-resource execution order."""
    n = len(spans)
    ms = max((sp[4] for sp in spans), default=0.0)
    succs = realized_succs9(sim, spans)
    indeg = [0] * n
    for ss in succs:
        for s in ss:
            indeg[s] += 1
    stack = [i for i in range(n) if indeg[i] == 0]
    order = []
    while stack:
        i = stack.pop()
        order.append(i)
        for s in succs[i]:
            indeg[s] -= 1
            if indeg[s] == 0:
                stack.append(s)
    assert len(order) == n, 'realized edge set must be acyclic'
    lf = [ms] * n
    for i in reversed(order):
        for s in succs[i]:
            cand = lf[s] - (spans[s][4] - spans[s][3])
            if cand < lf[i]:
                lf[i] = cand
    return [lf[i] - spans[i][4] for i in range(n)]


def realized_succs9(sim, spans):
    """analyze::critpath::realized_succs — dep edges plus the realized
    per-resource execution order."""
    n = len(spans)
    succs = [[] for _ in range(n)]
    for i, t in enumerate(sim.tasks):
        for d in t[3]:
            succs[d].append(i)
    by_res = {}
    for sp in spans:
        if sp[2] != FREE:
            by_res.setdefault(sp[2], []).append(sp[0])
    for ids in by_res.values():
        ids.sort(key=lambda i: (spans[i][3], spans[i][4], i))
        for a, b in zip(ids, ids[1:]):
            succs[a].append(b)
    return succs


def makespan_with_zeroed9(sim, spans, zero=None):
    """analyze::critpath::makespan_with_zeroed — forward CPM pass over
    the realized edge set with task `zero`'s duration set to 0. Not an
    engine re-run: list scheduling is not anomaly-free (zeroing the
    slack-carrying Gate chunk of the Top1/pipe2 corpus timeline reorders
    a compute queue and moves the re-simulated makespan), but over the
    realized order slack is exactly the do-nothing budget."""
    n = len(spans)
    succs = realized_succs9(sim, spans)
    indeg = [0] * n
    for ss in succs:
        for s in ss:
            indeg[s] += 1
    stack = [i for i in range(n) if indeg[i] == 0]
    es = [0.0] * n
    ms = 0.0
    seen = 0
    while stack:
        i = stack.pop()
        seen += 1
        dur = 0.0 if i == zero else sim.tasks[i][2]
        ef = es[i] + dur
        if ef > ms:
            ms = ef
        for s in succs[i]:
            if ef > es[s]:
                es[s] = ef
            indeg[s] -= 1
            if indeg[s] == 0:
                stack.append(s)
    assert seen == n, 'realized edge set must be acyclic'
    return ms


def category9(label, res):
    if res[0] in ('h2d', 'd2h'):
        return 'migration'
    if label.startswith('A2A-D'):
        return 'dispatch'
    if label.startswith('A2A-C'):
        return 'combine'
    if label.startswith('Expert'):
        return 'expert'
    return 'backbone'


def attribute9(spans, blockers):
    """analyze::critpath::attribute — category sums in path order, idle
    subtracted last (matching the Rust association exactly)."""
    ms = max((sp[4] for sp in spans), default=0.0)
    a = {'makespan': ms, 'backbone': 0.0, 'expert': 0.0, 'dispatch': 0.0,
         'combine': 0.0, 'migration': 0.0}
    for i in critical_path9(spans, blockers):
        sp = spans[i]
        a[category9(sp[1], sp[2])] += sp[4] - sp[3]
    a['idle'] = ms - (a['backbone'] + a['expert'] + a['dispatch']
                      + a['combine'] + a['migration'])
    return a


def merge9(ivs):
    out = []
    for s, e in sorted(ivs, key=lambda t: (t[0], t[1])):
        if out and s <= out[-1][1]:
            if e > out[-1][1]:
                out[-1][1] = e
            continue
        out.append([s, e])
    return out


def overlap_len9(merged, s, e):
    acc = 0.0
    for a, b in merged:
        acc += max(min(b, e) - max(a, s), 0.0)
    return acc


def comm_overlap9(spans, dpn):
    """analyze::overlap::comm_overlap — (total, hidden)."""
    assert dpn > 0
    compute = {}
    for sp in spans:
        if sp[2][0] == 'compute':
            compute.setdefault(sp[2][1], []).append((sp[3], sp[4]))
    total = 0.0
    hidden = 0.0
    for sp in spans:
        if sp[2][0] == 'comm':
            devs = [sp[2][1]]
        elif sp[2][0] == 'link':
            devs = list(range(sp[2][1] * dpn, (sp[2][1] + 1) * dpn))
        else:
            continue
        total += sp[4] - sp[3]
        ivs = []
        for d in devs:
            ivs.extend(compute.get(d, []))
        hidden += overlap_len9(merge9(ivs), sp[3], sp[4])
    return total, hidden


def utilization9(spans):
    """analyze::overlap::utilization — [(resource, busy, util)] in
    Resource order, Free skipped."""
    ms = max((sp[4] for sp in spans), default=0.0)
    busy = {}
    for sp in spans:
        if sp[2] != FREE:
            busy[sp[2]] = busy.get(sp[2], 0.0) + (sp[4] - sp[3])
    return [(r, b, b / ms if ms > 0.0 else 0.0)
            for r, b in sorted(busy.items(), key=lambda kv: res_key9(kv[0]))]


def stage_bubbles9(spans, stages, devices_per_stage):
    ms = max((sp[4] for sp in spans), default=0.0)
    out = []
    for st in range(stages):
        lo = st * devices_per_stage
        hi = lo + devices_per_stage
        ivs = [(sp[3], sp[4]) for sp in spans
               if sp[2][0] == 'compute' and lo <= sp[2][1] < hi]
        busy = sum(b - a for a, b in merge9(ivs))
        out.append(1.0 - busy / ms if ms > 0.0 else 0.0)
    return out


# --- analyze/export.rs + util/json.rs ---------------------------------

def row_label9(r):
    return 'free' if r == FREE else '%s[%d]' % (r[0], r[1])


def node_of9(r, dpn):
    if r == FREE:
        return 0
    if r[0] == 'link':
        return r[1]
    return r[1] // dpn


def json9(v):
    """util::json::Json::to_string — sorted object keys, compact
    separators, every number on the integer fast-path (asserted: the
    pinned trace is dyadic, so each microsecond value is exact)."""
    if isinstance(v, bool):
        return 'true' if v else 'false'
    if isinstance(v, (int, float)):
        f = float(v)
        assert f == int(f) and abs(f) < 1e15, ('non-integer trace value', v)
        return str(int(f))
    if isinstance(v, str):
        assert '"' not in v and '\\' not in v
        return '"' + v + '"'
    if isinstance(v, list):
        return '[' + ','.join(json9(x) for x in v) + ']'
    assert isinstance(v, dict), v
    return '{' + ','.join('"%s":%s' % (k, json9(x))
                          for k, x in sorted(v.items())) + '}'


def chrome_trace9(sim, spans, blockers, dpn):
    """analyze::export::chrome_trace — metadata events first (processes,
    then threads, in sorted order), then spans in id order."""
    assert dpn > 0
    on_path = set(critical_path9(spans, blockers))
    slacks = slack9(sim, spans)
    resources = sorted({sp[2] for sp in spans}, key=res_key9)
    tid = {r: i for i, r in enumerate(resources)}
    events = []
    for p in sorted({node_of9(r, dpn) for r in resources}):
        events.append({'args': {'name': 'node%d' % p},
                       'name': 'process_name', 'ph': 'M', 'pid': p})
    for r in resources:
        events.append({'args': {'name': row_label9(r)},
                       'name': 'thread_name', 'ph': 'M',
                       'pid': node_of9(r, dpn), 'tid': tid[r]})
    for sp in spans:
        events.append({'args': {'crit': sp[0] in on_path,
                                'slack_us': slacks[sp[0]] * 1e6},
                       'cat': 'sim', 'dur': (sp[4] - sp[3]) * 1e6,
                       'name': sp[1], 'ph': 'X',
                       'pid': node_of9(sp[2], dpn), 'tid': tid[sp[2]],
                       'ts': sp[3] * 1e6})
    return json9({'displayTimeUnit': 'ms', 'traceEvents': events})


# --- PR9 golden corpus additions --------------------------------------

# Every multi-device corpus sim models 2 devices per node (matches
# CORPUS_DPN in rust/tests/analyze_timeline.rs).
CORPUS_DPN9 = 2
TRACE_SIM9 = 'fleet:ScMoE/overlap-s2'


def corpus_sims9():
    """(name, Sim) for every golden corpus line, in corpus order, plus
    the rendered lines themselves — captured through the render_line
    choke point so the analysis corpus can never drift from the
    timeline corpus."""
    global _COLLECT9
    _COLLECT9 = []
    try:
        lines = generate_corpus_lines8()
        sims = list(_COLLECT9)
    finally:
        _COLLECT9 = None
    assert len(sims) == len(lines), 'render_line collection out of sync'
    return sims, lines


def analyze_line9(name, sim):
    """Mirror of analyze_line in rust/tests/analyze_timeline.rs."""
    spans, blockers = run_traced9(sim)
    path = critical_path9(spans, blockers)
    path_len = sum(spans[i][4] - spans[i][3] for i in path)
    a = attribute9(spans, blockers)
    total, hidden = comm_overlap9(spans, CORPUS_DPN9)
    return ('%s | crit %d %.6f | attr %.6f %.6f %.6f %.6f %.6f %.6f | '
            'comm %.6f %.6f'
            % (name, len(path), path_len, a['backbone'], a['expert'],
               a['dispatch'], a['combine'], a['migration'], a['idle'],
               total, hidden))


def fleet_trace9(sims):
    name, sim = next((n, s) for n, s in sims if n == TRACE_SIM9)
    spans, blockers = run_traced9(sim)
    return chrome_trace9(sim, spans, blockers, CORPUS_DPN9)


def validate_corpus9():
    """Validate all three golden artifacts (timelines, analyze lines,
    fleet trace) and print the combined count CI pins on."""
    golden_dir = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                              '..', '..', 'rust', 'tests', 'golden')
    sims, lines = corpus_sims9()
    bad = 0
    total = 0

    def check(fname, cur):
        nonlocal bad, total
        golden = [l for l in open(os.path.join(golden_dir, fname))
                  .read().splitlines() if l.strip() and not l.startswith('#')]
        total += len(cur)
        if len(golden) != len(cur):
            print('%s: line-count mismatch golden %d vs mirror %d'
                  % (fname, len(golden), len(cur)))
            bad += 1
        for g, cu in zip(golden, cur):
            if g != cu:
                bad += 1
                print('- ' + g)
                print('+ ' + cu)

    check('timelines.txt', lines)
    check('analyze.txt', [analyze_line9(n, s) for n, s in sims])
    total += 1
    trace_path = os.path.join(golden_dir, 'trace_fleet.json')
    if open(trace_path).read().rstrip('\n') != fleet_trace9(sims):
        bad += 1
        print('trace_fleet.json drifted from the mirror trace')
    print('golden corpus (PR9 analyze): %d lines, %d mismatches'
          % (total, bad))
    return bad == 0


ANALYZE_HEADER9 = """\
# Analysis-layer goldens: one line per golden-corpus simulation, in
# corpus order (the sims themselves are pinned span-by-span in
# timelines.txt). Fields: critical-path task count and summed duration
# (== makespan), makespan attribution in seconds
# (backbone/expert/dispatch/combine/migration/idle), and total/hidden
# communication time at devices_per_node = 2.
# Regenerate deliberately: python3 tools/des_mirror/mirror2.py --emit
"""


def emit_analyze9(path):
    sims, _ = corpus_sims9()
    cur = [analyze_line9(n, s) for n, s in sims]
    with open(path, 'w') as f:
        f.write(ANALYZE_HEADER9 + '\n'.join(cur) + '\n')
    print('emitted %d analyze lines to %s' % (len(cur), path))


def emit_trace9(path):
    sims, _ = corpus_sims9()
    with open(path, 'w') as f:
        f.write(fleet_trace9(sims) + '\n')
    print('emitted fleet trace to %s' % path)


def xl_topo_proxy9(topo):
    """report::efficiency::xl_topo_proxy_costs."""
    return TopoCosts4(topo_from_topology3(xl_compute_costs(), topo, 640,
                                          8192, 2.0))


def consistency_checks9():
    sims, _ = corpus_sims9()
    for name, sim in sims:
        spans, blockers = run_traced9(sim)
        # 1. the traced engine is a pure extension: spans bit-identical
        assert spans == sim.run(), ('traced spans drifted', name)
        ms = max((sp[4] for sp in spans), default=0.0)
        # 2. the blocking chain telescopes to the makespan, contiguously
        path = critical_path9(spans, blockers)
        plen = sum(spans[i][4] - spans[i][3] for i in path)
        assert abs(plen - ms) < 1e-9, ('critical path != makespan', name)
        for a, b in zip(path, path[1:]):
            assert spans[a][4] == spans[b][3], ('path gap', name)
        # 3. attribution partitions the makespan exactly; idle ~ 0
        at = attribute9(spans, blockers)
        cat = (at['backbone'] + at['expert'] + at['dispatch']
               + at['combine'] + at['migration'])
        assert abs(cat + at['idle'] - ms) < 1e-12, ('partition', name)
        assert abs(at['idle']) < 1e-9, ('idle', name, at['idle'])
        # 4. overlap bounds; slack non-negative and zero along the path
        total, hidden = comm_overlap9(spans, CORPUS_DPN9)
        assert -1e-12 <= hidden <= total + 1e-12, ('hidden bounds', name)
        sl = slack9(sim, spans)
        assert all(x >= -1e-9 for x in sl), ('negative slack', name)
        assert all(sl[i] <= 1e-9 for i in path), ('slack on path', name)
        # 5. the realized edge set replays the makespan bit-exactly, and
        #    zeroing any positive-slack task never moves it (over the
        #    realized order — an engine re-run is NOT anomaly-free:
        #    zeroing Top1/pipe2's slack-carrying Gate chunk reorders a
        #    compute queue and shifts the re-simulated makespan)
        assert makespan_with_zeroed9(sim, spans) == ms, ('replay', name)
        for i, x in enumerate(sl):
            if x <= 1e-9 or sim.tasks[i][2] == 0.0:
                continue
            assert abs(makespan_with_zeroed9(sim, spans, i) - ms) < 1e-9, \
                ('slack anomaly', name, i, x)
    # 6. XL grid: adaptive overlap hides strictly more comm than the
    #    sequential baseline (the PR's acceptance inequality)
    topo = SCENARIOS['4node-ib']
    dpn = topo.devices_per_node
    tc = xl_topo_proxy9(topo)
    st, sh = comm_overlap9(build_spec4(tc, ('std', 2), ('seq',)).run(), dpn)
    slot, _ = choose_expert_slot4(tc, ('scmoe', 1), ('overlap',))
    at_, ah = comm_overlap9(
        build_spec4(tc, ('scmoe', 1), ('overlap',), slot).run(), dpn)
    assert ah / at_ > sh / st, 'adaptive overlap must hide more comm'
    # 7. utilization lands in [0, 1] on every preset
    for nm, sc in SCENARIOS.items():
        tcs = xl_topo_proxy9(sc)
        slot, _ = choose_expert_slot4(tcs, ('scmoe', 1), ('overlap',))
        spans = build_spec4(tcs, ('scmoe', 1), ('overlap',), slot).run()
        for r, _b, u in utilization9(spans):
            assert 0.0 <= u <= 1.0 + 1e-12, ('utilization', nm, r, u)
            assert r != FREE
    # 8. the pinned fleet trace serializes on the integer fast-path only
    #    (json9 asserts) and carries the expected structure
    trace = fleet_trace9(sims)
    assert trace.startswith('{"displayTimeUnit":"ms","traceEvents":[')
    assert '"crit":true' in trace and '"thread_name"' in trace
    print('PR9 consistency checks: OK')


# --- PR9 study scenario (the numbers pinned in docs/STUDIES.md --------
# and printed by `scmoe report overlap` are minted here) ---------------

def study_row9(name, sim, dpn):
    """report::overlap_report::print_row."""
    spans, blockers = run_traced9(sim)
    a = attribute9(spans, blockers)
    total, hidden = comm_overlap9(spans, dpn)
    crit = len(critical_path9(spans, blockers))
    comps = [u for u in utilization9(spans) if u[0][0] == 'compute']
    cu = sum(u[2] for u in comps) / len(comps)
    hf = hidden / total if total > 0.0 else 0.0
    print('%-26s %8.3f %8.3f %8.3f %8.3f %8.3f %8.3f %6.1f%% %6.1f%% %5d'
          % (name, a['makespan'] * 1e3, a['backbone'] * 1e3,
             a['expert'] * 1e3, a['dispatch'] * 1e3, a['combine'] * 1e3,
             a['migration'] * 1e3, hf * 100.0, cu * 100.0, crit))


def study_header9():
    print('%-26s %8s %8s %8s %8s %8s %8s %7s %7s %5s'
          % ('row', 'total', 'backbone', 'expert', 'dispatch', 'combine',
             'migr', 'hidden', 'util', 'crit'))


def overlap_study9():
    """Mirror of `scmoe report overlap` (report/overlap_report.rs)."""
    topo = SCENARIOS['4node-ib']
    dpn = topo.devices_per_node
    tc = xl_topo_proxy9(topo)
    print('== makespan attribution x hidden comm (4node-ib, GPT3-XL '
          'proxy; all columns ms) ==')
    study_header9()
    study_row9('top2/seq', build_spec4(tc, ('std', 2), ('seq',)), dpn)
    study_row9('top2/pipe2', build_spec4(tc, ('std', 2), ('pipe', 2)), dpn)
    slot, _ = choose_expert_slot4(tc, ('scmoe', 1), ('overlap',))
    study_row9('scmoe/ovl (slot %d)' % (slot + 1),
               build_spec4(tc, ('scmoe', 1), ('overlap',), slot), dpn)
    oslot, _ = choose_expert_slot4(tc, ('scmoe', 1), ('overlap-pipe', 2))
    study_row9('scmoe/ovl+pipe2 (slot %d)' % (oslot + 1),
               build_spec4(tc, ('scmoe', 1), ('overlap-pipe', 2), oslot),
               dpn)
    # the drift study's migration step, reconstructed exactly as
    # `timeline_explorer --replace` / report/overlap_report.rs do
    base = xl_compute_costs()
    tables = replace_drift_tables(0.05, 11)
    blk = Placement.block(32, 32)
    est = AffinityEstimator(32, topo.n_devices // dpn, 1.0)
    est.observe(tables[0], topo.n_devices, dpn)
    measured = est.packed(topo.n_devices, dpn)
    plan = MigrationPlan.between(blk, measured, REPLACE_STUDY_EXPERT_BYTES)
    rtc = topo_from_routing4(base, topo, tables[0], blk, REPLACE_STUDY_BYTES)
    sim = build_spec4(rtc, ('scmoe', 1), ('seq',))
    plan.add_h2d_tasks(sim, REPLACE_STUDY_H2D)
    study_row9('replace/migrate-step', sim, dpn)
    # one whole-model pipeline row plus its stage-bubble fractions
    print()
    print('== whole-model pipeline (GPipe, m = 4, cross-layer '
          'placements) ==')
    study_header9()
    mtables = model_tables8(MODEL_STEPS, MODEL_LAYERS, MODEL_SEED)
    _, cross = model_grid_placements8(mtables[0])
    costs = model_layer_costs8(base, topo, REPLACE_STUDY_BYTES, mtables[0],
                               cross, MODEL_STAGES * 2)
    sim, _ = build_model_sim8([MODEL_SEQ_SPEC] * MODEL_LAYERS, MODEL_STAGES,
                              MODEL_STAGES * 2, GPIPE, costs, topo.n_devices,
                              topo.n_devices // dpn)
    study_row9('model/gpipe-m4', sim, dpn)
    bub = stage_bubbles9(sim.run(), MODEL_STAGES, topo.n_devices)
    print('stage bubbles: '
          + '  '.join('s%d %.1f%%' % (i, b * 100.0)
                      for i, b in enumerate(bub)))


if __name__ == '__main__':
    # Internal reductions first: the PR3 model must reproduce the seed
    # model bit-for-bit where applicable, the PR4 spec-driven model must
    # reproduce the PR3 builders wherever no load information exists
    # (plus balanced-load identity), the PR5 re-placement model must
    # reduce to the PR4 single-step schedules wherever no migration
    # fires, the PR6 serving loop must reduce to the PR5 scripted
    # timeline on a closed system, and the PR7 chaos layer must reduce
    # to the clean PR5/PR6 models at zero magnitude, and the PR8
    # whole-model layer must reduce to the per-layer PR5 timeline at
    # L=S=M=1 (and to per-layer packing at zero transition counts), and
    # the PR9 traced engine must reproduce the plain engine's spans
    # bit-for-bit while its analytics satisfy the critical-path algebra
    # on every corpus sim. Then validate the PR9 artifacts (timelines +
    # analyze lines + fleet trace) against the full golden corpus.
    # `--emit` deliberately regenerates the files; plain invocation (CI)
    # only validates and exits nonzero on drift.
    consistency_checks3()
    consistency_checks4()
    consistency_checks5()
    consistency_checks6()
    consistency_checks7()
    consistency_checks8()
    consistency_checks9()
    if '--study' in sys.argv:
        replace_study5()
        sys.exit(0)
    if '--serve-study' in sys.argv:
        serve_study6()
        sys.exit(0)
    if '--chaos-study' in sys.argv:
        chaos_study7()
        sys.exit(0)
    if '--model-study' in sys.argv:
        model_study8()
        sys.exit(0)
    if '--serve-hetero-study' in sys.argv:
        serve_hetero_study8()
        sys.exit(0)
    if '--overlap-study' in sys.argv:
        overlap_study9()
        sys.exit(0)
    if '--emit' in sys.argv:
        golden = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                              '..', '..', 'rust', 'tests', 'golden')
        emit_corpus8(os.path.join(golden, 'timelines.txt'))
        emit_analyze9(os.path.join(golden, 'analyze.txt'))
        emit_trace9(os.path.join(golden, 'trace_fleet.json'))
    ok = validate_corpus9()
    sys.exit(0 if ok else 1)
