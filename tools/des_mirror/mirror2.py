"""Extension of /tmp/mirror.py: golden-line rendering, validation against
rust/tests/golden/timelines.txt, plus mirrors of the PLANNED changes:
per-node intra links, dispatch/combine phase split, routed byte matrices,
Placement layouts, Rng port."""
import os
import sys
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from dataclasses import replace
from mirror import *
from mirror import SCENARIOS

MASK = (1 << 64) - 1


class Rng:
    def __init__(self, seed):
        self.state = (seed + 0x9E3779B97F4A7C15) & MASK

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return (z ^ (z >> 31)) & MASK

    def next_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        return self.next_u64() % n

    def range_f64(self, lo, hi):
        return lo + self.next_f64() * (hi - lo)


# ---------------------------------------------------------------- golden

def resource_token(r):
    kind = r[0]
    if kind == 'compute':
        return f'c{r[1]}'
    if kind == 'comm':
        return f'm{r[1]}'
    if kind == 'link':
        return f'l{r[1]}'
    if kind == 'h2d':
        return f'h{r[1]}'
    return 'f'


def render_line(name, sim):
    spans = sim.run()
    makespan = max((s[4] for s in spans), default=0.0)
    spans = sorted(spans, key=lambda s: (s[3], s[0]))
    toks = [f'{s[1]}@{resource_token(s[2])}@{s[3]:.6f}' for s in spans]
    return f'{name} | makespan {makespan:.6f} | ' + ' '.join(toks)


def dyadic_costs():
    return BlockCosts(1.0, 0.75, 0.75, 0.0625, 0.0625, 0.0625, 0.5, 0.8125)


def dyadic_fleet():
    fast = dyadic_costs()
    slow = BlockCosts(2.0, 1.5, 1.5, 0.125, 0.125, 0.125, 1.0, 0.8125)
    return TopoCosts([replace(fast), fast, replace(slow), slow],
                     [0.25] * 4, [0.5] * 2, 2)


def kind_label(kind):
    t, k = kind
    if t == 'std':
        return f'Top{k}'
    if t == 'shared':
        return 'Top1+SE1'
    return 'ScMoE' if k == 1 else f'ScMoE-{k}'


def generate_seed_lines():
    c = dyadic_costs()
    lines = []
    kinds = [('std', 1), ('std', 2), ('std', 3), ('shared', 1),
             ('scmoe', 1), ('scmoe', 2)]
    for kind in kinds:
        if kind[0] == 'std':
            strategies = [('seq',), ('pipe', 2), ('pipe', 4)]
        elif kind[0] == 'shared':
            strategies = [('seq',), ('pipe', 1), ('pipe', 2)]
        else:
            strategies = [('seq',), ('pipe', 2)]
        for strategy in strategies:
            if strategy[0] == 'seq':
                slabel = 'seq'
            else:
                slabel = f'pipe{strategy[1]}'
            name = f'{kind_label(kind)}/{slabel}'
            lines.append(render_line(name, build_pair_schedule(c, kind, strategy, 0)))
        if kind[0] == 'scmoe':
            for slot in range(4):
                s = build_pair_schedule(c, kind, ('overlap',), slot)
                lines.append(render_line(f'{kind_label(kind)}/overlap-s{slot}', s))
            for slot in range(4):
                s = build_pair_schedule(c, kind, ('overlap-pipe', 2), slot)
                lines.append(render_line(
                    f'{kind_label(kind)}/overlap+pipe2-s{slot}', s))
    tf = dyadic_fleet()
    lines.append(render_line('fleet:Top2/seq',
                             build_pair_schedule_topo(tf, ('std', 2), ('seq',), 0)))
    lines.append(render_line('fleet:Top2/pipe2',
                             build_pair_schedule_topo(tf, ('std', 2), ('pipe', 2), 0)))
    for slot in range(4):
        lines.append(render_line(
            f'fleet:ScMoE/overlap-s{slot}',
            build_pair_schedule_topo(tf, ('scmoe', 1), ('overlap',), slot)))
    return lines


def validate_seed_golden():
    golden_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                               '..', '..', 'rust', 'tests', 'golden', 'timelines.txt')
    golden = [l for l in open(golden_path).read().splitlines()
              if l.strip() and not l.startswith('#')]
    current = generate_seed_lines()
    golden = golden[:len(current)]  # routed lines are validated by __main__
    bad = 0
    for g, cu in zip(golden, current):
        if g != cu:
            bad += 1
            print('- ' + g)
            print('+ ' + cu)
    print(f'seed golden: {len(golden)} lines, {bad} mismatches')
    return bad == 0


# ------------------------------------------- planned: per-node intra links

def a2a_time_pn(bytes_, n_devices, devices_per_node, intra_links, inter):
    n_nodes = n_devices // devices_per_node
    node_of = lambda d: d // devices_per_node
    worst_dev = 0.0
    for src in range(n_devices):
        out_bytes = 0
        msgs = 0
        for dst in range(n_devices):
            if dst == src:
                continue
            b = bytes_[src * n_devices + dst]
            if b > 0:
                out_bytes += b
                msgs += 1
        l = intra_links[node_of(src)]
        t = l.alpha * float(msgs) + float(out_bytes) / l.beta
        worst_dev = max(worst_dev, t)
    worst_node = 0.0
    if inter is not None and n_nodes > 1:
        for node in range(n_nodes):
            cross = 0
            for src in range(n_devices):
                if node_of(src) != node:
                    continue
                for dst in range(n_devices):
                    if node_of(dst) != node:
                        cross += bytes_[src * n_devices + dst]
            if cross > 0:
                worst_node = max(worst_node, inter.alpha + float(cross) / inter.beta)
    return max(worst_dev, worst_node)


def a2a_decompose_pn(bytes_, n_devices, devices_per_node, intra_links, inter):
    n_nodes = n_devices // devices_per_node
    node_of = lambda d: d // devices_per_node
    split = inter is not None and n_nodes > 1
    intra_phase = []
    for src in range(n_devices):
        out_bytes = 0
        msgs = 0
        for dst in range(n_devices):
            if dst == src or (split and node_of(dst) != node_of(src)):
                continue
            b = bytes_[src * n_devices + dst]
            if b > 0:
                out_bytes += b
                msgs += 1
        l = intra_links[node_of(src)]
        intra_phase.append(l.alpha * float(msgs) + float(out_bytes) / l.beta)
    inter_phase = []
    if split:
        for node in range(n_nodes):
            cross = 0
            for src in range(n_devices):
                if node_of(src) != node:
                    continue
                for dst in range(n_devices):
                    if node_of(dst) != node:
                        cross += bytes_[src * n_devices + dst]
            inter_phase.append(inter.alpha + float(cross) / inter.beta
                               if cross > 0 else 0.0)
    return intra_phase, inter_phase


class TopoCosts2(TopoCosts):
    """TopoCosts with the planned combine-direction phase vectors."""

    def __init__(self, per_device, a2a_intra_k1, a2a_inter_k1, devices_per_node,
                 intra_c=None, inter_c=None):
        super().__init__(per_device, a2a_intra_k1, a2a_inter_k1, devices_per_node)
        self.a2a_intra_c_k1 = intra_c or []
        self.a2a_inter_c_k1 = inter_c or []

    def a2a_intra_c(self, d, k):
        v = self.a2a_intra_c_k1 if self.a2a_intra_c_k1 else self.a2a_intra_k1
        return v[d] * float(k)

    def a2a_inter_c(self, n, k):
        v = self.a2a_inter_c_k1 if self.a2a_inter_c_k1 else self.a2a_inter_k1
        return v[n] * float(k)


# monkey-patch base TopoCosts with symmetric fallbacks so existing builders
# in mirror.py can be reused once edited; instead we re-define the builders
# below with combine-aware phases, mirroring the planned Rust edit.
TopoCosts.a2a_intra_c = lambda self, d, k: (
    (self.a2a_intra_c_k1 if getattr(self, 'a2a_intra_c_k1', []) else
     self.a2a_intra_k1)[d] * float(k))
TopoCosts.a2a_inter_c = lambda self, n, k: (
    (self.a2a_inter_c_k1 if getattr(self, 'a2a_inter_c_k1', []) else
     self.a2a_inter_k1)[n] * float(k))


import mirror as _m


def _patch_builders_for_combine():
    """Rewrite the three topo builders to use a2a_intra_c/a2a_inter_c for
    A2A-C tasks, mirroring the planned Rust change."""
    src = open(os.path.join(os.path.dirname(os.path.abspath(__file__)), 'mirror.py')).read()
    # sequential: comb uses tc.a2a_intra(d, k) -> tc.a2a_intra_c(d, k)
    # we patch by executing modified source in a new namespace
    src = src.replace(
        'comb.append(sim.add("A2A-C", comm(d), tc.a2a_intra(d, k), [experts[d]]))',
        'comb.append(sim.add("A2A-C", comm(d), tc.a2a_intra_c(d, k), [experts[d]]))')
    src = src.replace(
        'comb.append(sim.add("A2A-Cx", link(node), tc.a2a_inter(node, k), deps))',
        'comb.append(sim.add("A2A-Cx", link(node), tc.a2a_inter_c(node, k), deps))')
    src = src.replace(
        'combines.append(sim.add(f"A2A-C{i}", comm(d), tc.a2a_intra(d, k) / fc,\n'
        '                                    [experts_i[d]]))',
        'combines.append(sim.add(f"A2A-C{i}", comm(d), tc.a2a_intra_c(d, k) / fc,\n'
        '                                    [experts_i[d]]))')
    src = src.replace(
        'combines.append(sim.add(f"A2A-Cx{i}", link(node),\n'
        '                                    tc.a2a_inter(node, k) / fc, deps))',
        'combines.append(sim.add(f"A2A-Cx{i}", link(node),\n'
        '                                    tc.a2a_inter_c(node, k) / fc, deps))')
    src = src.replace(
        'combines.append(sim.add(f"A2A-C{i}", comm(d), tc.a2a_intra(d, k) / fc,\n'
        '                                    [experts_by_dev[d][i]]))',
        'combines.append(sim.add(f"A2A-C{i}", comm(d), tc.a2a_intra_c(d, k) / fc,\n'
        '                                    [experts_by_dev[d][i]]))')
    src = src.replace(
        'combines.append(sim.add(f"A2A-Cx{i}", link(node),\n'
        '                                    tc.a2a_inter(node, k) / fc, deps))',
        'combines.append(sim.add(f"A2A-Cx{i}", link(node),\n'
        '                                    tc.a2a_inter_c(node, k) / fc, deps))')
    ns = {}
    exec(src, ns)
    return ns


NS = _patch_builders_for_combine()
build_pair_schedule_topo_c = NS['build_pair_schedule_topo']


def choose_expert_slot_topo_c(tc, kind, strat):
    best = (0, float('inf'))
    for slot in range(4):
        t = build_pair_schedule_topo_c(tc, kind, strat, slot).makespan()
        if t < best[1]:
            best = (slot, t)
    return best


# topologies with the planned node_intra field
def topo_intra_links(topo, node_intra=None):
    n_nodes = topo.n_devices // topo.devices_per_node
    return node_intra if node_intra else [topo.intra] * n_nodes


def topo_from_topology_pn(base, topo, tokens_per_device, token_bytes, cf,
                          node_intra=None):
    bpp = int((float(tokens_per_device) * cf / float(topo.n_devices)) * float(token_bytes))
    m = uniform_a2a_bytes(topo.n_devices, bpp)
    links = topo_intra_links(topo, node_intra)
    intra, inter = a2a_decompose_pn(m, topo.n_devices, topo.devices_per_node,
                                    links, topo.inter)
    flat = a2a_time_pn(m, topo.n_devices, topo.devices_per_node, links, topo.inter)
    per_device = []
    for d in range(topo.n_devices):
        s = topo.device_compute_scale(d)
        per_device.append(BlockCosts(base.attn / s, base.mlp / s, base.se / s,
                                     base.gate / s, base.encode / s,
                                     base.decode / s, base.expert_k1 / s, flat))
    tc = TopoCosts(per_device, intra, inter, topo.devices_per_node)
    tc.a2a_intra_c_k1 = []
    tc.a2a_inter_c_k1 = []
    return tc


def transpose(m, n):
    out = [0] * (n * n)
    for s in range(n):
        for d in range(n):
            out[d * n + s] = m[s * n + d]
    return out


def topo_from_routed(base, topo, disp_bytes, k_norm, node_intra=None):
    n = topo.n_devices
    links = topo_intra_links(topo, node_intra)
    comb_bytes = transpose(disp_bytes, n)
    di, dx = a2a_decompose_pn(disp_bytes, n, topo.devices_per_node, links, topo.inter)
    ci, cx = a2a_decompose_pn(comb_bytes, n, topo.devices_per_node, links, topo.inter)
    kf = float(k_norm)
    flat = max(a2a_time_pn(disp_bytes, n, topo.devices_per_node, links, topo.inter),
               a2a_time_pn(comb_bytes, n, topo.devices_per_node, links, topo.inter)) / kf
    di = [x / kf for x in di]
    dx = [x / kf for x in dx]
    ci = [x / kf for x in ci]
    cx = [x / kf for x in cx]
    per_device = []
    for d in range(n):
        s = topo.device_compute_scale(d)
        per_device.append(BlockCosts(base.attn / s, base.mlp / s, base.se / s,
                                     base.gate / s, base.encode / s,
                                     base.decode / s, base.expert_k1 / s, flat))
    tc = TopoCosts(per_device, di, dx, topo.devices_per_node)
    tc.a2a_intra_c_k1 = ci
    tc.a2a_inter_c_k1 = cx
    return tc


# --------------------------------------------------- routing + placement

class RoutingTable:
    def __init__(self, indices, weights, n_tokens, k, n_experts, capacity):
        assert len(indices) == n_tokens * k
        self.n_tokens = n_tokens
        self.n_experts = n_experts
        self.capacity = capacity
        self.k = k
        self.routes = []  # (token, k_slot, expert, slot, weight)
        next_slot = [0] * n_experts
        self.demand = [0] * n_experts
        self.dropped = 0
        for t in range(n_tokens):
            for kk in range(k):
                e = indices[t * k + kk]
                assert 0 <= e < n_experts
                self.demand[e] += 1
                if next_slot[e] < capacity:
                    self.routes.append((t, kk, e, next_slot[e], weights[t * k + kk]))
                    next_slot[e] += 1
                else:
                    self.dropped += 1
        self.load = next_slot

    def a2a_bytes_placed(self, placement, token_bytes):
        n_devices = placement.n_devices
        tokens_per_device = -(-self.n_tokens // n_devices)
        mat = [0] * (n_devices * n_devices)
        for (t, kk, e, slot, w) in self.routes:
            src = min(t // tokens_per_device, n_devices - 1)
            dst = placement.device_of(e)
            mat[src * n_devices + dst] += token_bytes
        return mat


class Placement:
    def __init__(self, n_experts, n_devices, mapping):
        self.n_experts = n_experts
        self.n_devices = n_devices
        self.map = mapping

    @staticmethod
    def block(n_experts, n_devices):
        assert n_experts % n_devices == 0
        per = n_experts // n_devices
        return Placement(n_experts, n_devices, [e // per for e in range(n_experts)])

    @staticmethod
    def affinity_packed(rt, n_devices, devices_per_node):
        assert n_devices % devices_per_node == 0
        n_nodes = n_devices // devices_per_node
        assert rt.n_experts % n_nodes == 0
        tokens_per_device = -(-rt.n_tokens // n_devices)
        aff = [[0] * n_nodes for _ in range(rt.n_experts)]
        for (t, kk, e, slot, w) in rt.routes:
            src = min(t // tokens_per_device, n_devices - 1)
            aff[e][src // devices_per_node] += 1
        order = sorted(range(rt.n_experts),
                       key=lambda e: (-sum(aff[e]), e))
        cap = rt.n_experts // n_nodes
        node_load = [0] * n_nodes
        mapping = [0] * rt.n_experts
        for e in order:
            best = None
            best_aff = 0
            for node in range(n_nodes):
                if node_load[node] >= cap:
                    continue
                a = aff[e][node]
                if best is None or a > best_aff:
                    best = node
                    best_aff = a
            dev = best * devices_per_node + node_load[best] % devices_per_node
            mapping[e] = dev
            node_load[best] += 1
        return Placement(rt.n_experts, n_devices, mapping)

    @staticmethod
    def imbalance_skewed(n_experts, n_devices, pack):
        assert pack >= 1 and n_experts % pack == 0
        used = n_experts // pack
        assert 1 <= used <= n_devices
        return Placement(n_experts, n_devices,
                         [e // pack for e in range(n_experts)])

    def device_of(self, e):
        return self.map[e]


if __name__ == '__main__':
    # validate the full golden corpus (seed lines + routed placements)
    from mirror import Topology as _T
    lines = generate_seed_lines()
    _topo = _T(4, 2, LinkModel(0.0625, 1024.0), LinkModel(0.125, 512.0), 1.0, None)
    _base = ComputeCosts(1.0, 0.75, 0.75, 0.0625, 0.0625, 0.0625, 0.5)
    _rt = RoutingTable([0, 2, 0, 2, 2, 0, 0, 2, 1, 3, 3, 1, 3, 1, 3, 3],
                       [1.0] * 16, 16, 1, 4, 16)
    for _name, _p in [('block', Placement.block(4, 4)),
                      ('affinity', Placement.affinity_packed(_rt, 4, 2)),
                      ('skewed', Placement.imbalance_skewed(4, 4, 2))]:
        _tc = topo_from_routed(_base, _topo, _rt.a2a_bytes_placed(_p, 64), _rt.k)
        lines.append(render_line(f'routed:{_name}/seq',
                     build_pair_schedule_topo_c(_tc, ('scmoe', 1), ('seq',), 0)))
        lines.append(render_line(f'routed:{_name}/overlap-s2',
                     build_pair_schedule_topo_c(_tc, ('scmoe', 1), ('overlap',), 2)))
    golden_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                               '..', '..', 'rust', 'tests', 'golden', 'timelines.txt')
    golden = [l for l in open(golden_path).read().splitlines()
              if l.strip() and not l.startswith('#')]
    assert len(golden) == len(lines), (len(golden), len(lines))
    bad = 0
    for g, cu in zip(golden, lines):
        if g != cu:
            bad += 1
            print('- ' + g)
            print('+ ' + cu)
    print(f'golden corpus: {len(golden)} lines, {bad} mismatches')
    # combine-aware builders with empty combine vectors reduce to seed builders
    tf = dyadic_fleet()
    tf.a2a_intra_c_k1 = []
    tf.a2a_inter_c_k1 = []
    for slot in range(4):
        a = render_line('x', build_pair_schedule_topo(tf, ('scmoe', 1), ('overlap',), slot))
        b = render_line('x', build_pair_schedule_topo_c(tf, ('scmoe', 1), ('overlap',), slot))
        assert a == b, (slot, a, b)
    print('combine-aware builders reduce to seed builders: OK')
    sys.exit(1 if bad else 0)
