"""Faithful Python mirror of the scmoe Rust DES + schedule builders.

Used offline (no Rust toolchain in this container) to
  1. sanity-check the new topology-aware builders' properties,
  2. choose test constants (adaptive slots per preset),
  3. generate rust/tests/golden/timelines.txt.

Every function transcribes the Rust source line-by-line; f64 arithmetic is
IEEE double in both languages, so results are bit-identical.
"""
import heapq
from dataclasses import dataclass, replace
from typing import Optional

FREE = ("free",)

def comp(d): return ("compute", d)
def comm(d): return ("comm", d)
def link(n): return ("link", n)

class Sim:
    def __init__(self):
        self.tasks = []  # (label, resource, duration, deps)

    def add(self, label, resource, duration, deps):
        i = len(self.tasks)
        for d in deps:
            assert d < i
        assert duration >= 0.0
        self.tasks.append((label, resource, float(duration), list(deps)))
        return i

    def run(self):
        n = len(self.tasks)
        remaining = [len(t[3]) for t in self.tasks]
        dependents = [[] for _ in range(n)]
        for i, t in enumerate(self.tasks):
            for d in t[3]:
                dependents[d].append(i)
        heap = []
        ready_at = [0.0] * n
        for i, t in enumerate(self.tasks):
            if not t[3]:
                heapq.heappush(heap, (0.0, i))
        free = {}
        spans = [None] * n
        done = 0
        while heap:
            _, i = heapq.heappop(heap)
            label, res, dur, deps = self.tasks[i]
            if res == FREE:
                start = ready_at[i]
            else:
                start = max(free.get(res, 0.0), ready_at[i])
            end = start + dur
            if res != FREE:
                free[res] = end
            spans[i] = (i, label, res, start, end)
            done += 1
            for dep in dependents[i]:
                ready_at[dep] = max(ready_at[dep], end)
                remaining[dep] -= 1
                if remaining[dep] == 0:
                    heapq.heappush(heap, (ready_at[dep], dep))
        assert done == n, "cycle"
        return spans

    def makespan(self):
        return max((s[4] for s in self.run()), default=0.0)


# --- costs ------------------------------------------------------------------

@dataclass
class BlockCosts:
    attn: float; mlp: float; se: float; gate: float
    encode: float; decode: float; expert_k1: float; a2a_k1: float

    def expert(self, k): return self.expert_k1 * float(k)
    def a2a(self, k): return self.a2a_k1 * float(k)

@dataclass
class ComputeCosts:
    attn: float; mlp: float; se: float; gate: float
    encode: float; decode: float; expert_k1: float

def swin_proxy():
    return ComputeCosts(1.00e-3, 0.75e-3, 0.75e-3, 0.06e-3, 0.05e-3, 0.05e-3, 0.80e-3)

@dataclass
class LinkModel:
    alpha: float; beta: float

def pcie(): return LinkModel(10e-6, 2.9e9)
def nvlink(): return LinkModel(1e-6, 50e9)
def ethernet(): return LinkModel(30e-6, 30e9)
def infiniband(): return LinkModel(5e-6, 60e9)

def uniform_a2a_bytes(n, bpp):
    m = [0] * (n * n)
    for s in range(n):
        for d in range(n):
            if s != d:
                m[s * n + d] = bpp
    return m

def a2a_time(bytes_, n_devices, devices_per_node, intra, inter):
    n_nodes = n_devices // devices_per_node
    node_of = lambda d: d // devices_per_node
    worst_dev = 0.0
    for src in range(n_devices):
        out_bytes = 0; msgs = 0
        for dst in range(n_devices):
            if dst == src: continue
            b = bytes_[src * n_devices + dst]
            if b > 0:
                out_bytes += b; msgs += 1
        t = intra.alpha * float(msgs) + float(out_bytes) / intra.beta
        worst_dev = max(worst_dev, t)
    worst_node = 0.0
    if inter is not None and n_nodes > 1:
        for node in range(n_nodes):
            cross = 0
            for src in range(n_devices):
                if node_of(src) != node: continue
                for dst in range(n_devices):
                    if node_of(dst) != node:
                        cross += bytes_[src * n_devices + dst]
            if cross > 0:
                worst_node = max(worst_node, inter.alpha + float(cross) / inter.beta)
    return max(worst_dev, worst_node)

def a2a_decompose(bytes_, n_devices, devices_per_node, intra, inter):
    n_nodes = n_devices // devices_per_node
    node_of = lambda d: d // devices_per_node
    split = inter is not None and n_nodes > 1
    intra_phase = []
    for src in range(n_devices):
        out_bytes = 0; msgs = 0
        for dst in range(n_devices):
            if dst == src or (split and node_of(dst) != node_of(src)):
                continue
            b = bytes_[src * n_devices + dst]
            if b > 0:
                out_bytes += b; msgs += 1
        intra_phase.append(intra.alpha * float(msgs) + float(out_bytes) / intra.beta)
    inter_phase = []
    if split:
        for node in range(n_nodes):
            cross = 0
            for src in range(n_devices):
                if node_of(src) != node: continue
                for dst in range(n_devices):
                    if node_of(dst) != node:
                        cross += bytes_[src * n_devices + dst]
            inter_phase.append(inter.alpha + float(cross) / inter.beta if cross > 0 else 0.0)
    return intra_phase, inter_phase

@dataclass
class Topology:
    n_devices: int; devices_per_node: int
    intra: LinkModel; inter: Optional[LinkModel]
    compute_scale: float; device_scales: Optional[list]

    def device_compute_scale(self, d):
        return self.device_scales[d] if self.device_scales else self.compute_scale

SCENARIOS = {
    "pcie": Topology(8, 8, pcie(), None, 1.0, None),
    "nvlink": Topology(8, 8, nvlink(), None, 1.9, None),
    "2node": Topology(16, 8, nvlink(), ethernet(), 1.9, None),
    "4node-ib": Topology(32, 8, nvlink(), infiniband(), 1.9, None),
    "hetero": Topology(8, 4, nvlink(), ethernet(), 1.9,
                       [1.9, 1.9, 1.9, 1.9, 1.0, 1.0, 1.0, 1.0]),
}

def block_from_topology(base, topo, tokens_per_device, token_bytes, cf):
    s = topo.compute_scale
    bpp = int((float(tokens_per_device) * cf / float(topo.n_devices)) * float(token_bytes))
    m = uniform_a2a_bytes(topo.n_devices, bpp)
    a2a_k1 = a2a_time(m, topo.n_devices, topo.devices_per_node, topo.intra, topo.inter)
    return BlockCosts(base.attn / s, base.mlp / s, base.se / s, base.gate / s,
                      base.encode / s, base.decode / s, base.expert_k1 / s, a2a_k1)

@dataclass
class TopoCosts:
    per_device: list
    a2a_intra_k1: list
    a2a_inter_k1: list
    devices_per_node: int

    def n_devices(self): return len(self.per_device)
    def devices_of(self, node):
        lo = node * self.devices_per_node
        return range(lo, min(lo + self.devices_per_node, self.n_devices()))
    def a2a_intra(self, d, k): return self.a2a_intra_k1[d] * float(k)
    def a2a_inter(self, n, k): return self.a2a_inter_k1[n] * float(k)

def topo_from_block(c):
    return TopoCosts([replace(c)], [c.a2a_k1], [], 1)

def topo_from_topology(base, topo, tokens_per_device, token_bytes, cf):
    bpp = int((float(tokens_per_device) * cf / float(topo.n_devices)) * float(token_bytes))
    m = uniform_a2a_bytes(topo.n_devices, bpp)
    intra, inter = a2a_decompose(m, topo.n_devices, topo.devices_per_node,
                                 topo.intra, topo.inter)
    flat = a2a_time(m, topo.n_devices, topo.devices_per_node, topo.intra, topo.inter)
    per_device = []
    for d in range(topo.n_devices):
        s = topo.device_compute_scale(d)
        per_device.append(BlockCosts(base.attn / s, base.mlp / s, base.se / s,
                                     base.gate / s, base.encode / s, base.decode / s,
                                     base.expert_k1 / s, flat))
    return TopoCosts(per_device, intra, inter, topo.devices_per_node)


# --- kinds / strategies -----------------------------------------------------

def routed_k(kind):
    name, k = kind
    return k

def has_shared_expert(kind):
    return kind[0] in ("shared", "scmoe")

# kind: ("std", k) | ("shared", 1) | ("scmoe", k)

# --- legacy single-device builders (schedule.rs) ----------------------------

DEV = 0

def build_sequential(c, kind, k):
    sim = Sim()
    attn_l = sim.add("Attn(l)", comp(DEV), c.attn, [])
    mlp_l = sim.add("MLP(l)", comp(DEV), c.mlp, [attn_l])
    attn_m = sim.add("Attn(l+1)", comp(DEV), c.attn, [mlp_l])
    gate = sim.add("Gate", comp(DEV), c.gate, [attn_m])
    enc = sim.add("Encode", comp(DEV), c.encode, [gate])
    disp = sim.add("A2A-D", comm(DEV), c.a2a(k), [enc])
    expert = sim.add("Expert", comp(DEV), c.expert(k), [disp])
    comb = sim.add("A2A-C", comm(DEV), c.a2a(k), [expert])
    decode_deps = [comb]
    if has_shared_expert(kind):
        se = sim.add("SE", comp(DEV), c.se, [attn_m])
        decode_deps.append(se)
    sim.add("Decode", comp(DEV), c.decode, decode_deps)
    return sim

def build_pipelined(c, kind, k, chunks):
    sim = Sim()
    attn_l = sim.add("Attn(l)", comp(DEV), c.attn, [])
    mlp_l = sim.add("MLP(l)", comp(DEV), c.mlp, [attn_l])
    attn_m = sim.add("Attn(l+1)", comp(DEV), c.attn, [mlp_l])
    gate = sim.add("Gate", comp(DEV), c.gate, [attn_m])
    enc = sim.add("Encode", comp(DEV), c.encode, [gate])
    fc = float(chunks)
    combines = []
    prev_disp = None
    for i in range(chunks):
        dd = [enc, prev_disp] if prev_disp is not None else [enc]
        disp = sim.add(f"A2A-D{i}", comm(DEV), c.a2a(k) / fc, dd)
        prev_disp = disp
        expert = sim.add(f"Expert{i}", comp(DEV), c.expert(k) / fc, [disp])
        comb = sim.add(f"A2A-C{i}", comm(DEV), c.a2a(k) / fc, [expert])
        combines.append(comb)
    decode_deps = combines[:]
    if has_shared_expert(kind):
        se = sim.add("SE", comp(DEV), c.se, [attn_m])
        decode_deps.append(se)
    sim.add("Decode", comp(DEV), c.decode, decode_deps)
    return sim

def build_overlap(c, kind, k, slot, chunks):
    assert slot <= 3 and chunks >= 1
    sim = Sim()
    attn_l = sim.add("Attn(l)", comp(DEV), c.attn, [])
    gate = sim.add("Gate", comp(DEV), c.gate, [attn_l])
    enc = sim.add("Encode", comp(DEV), c.encode, [gate])
    fc = float(chunks)
    dispatches = []
    prev = None
    for i in range(chunks):
        deps = [enc, prev] if prev is not None else [enc]
        d = sim.add(f"A2A-D{i}", comm(DEV), c.a2a(k) / fc, deps)
        dispatches.append(d)
        prev = d
    experts = []
    last_backbone = attn_l
    window = [("MLP(l)", c.mlp), ("Attn(l+1)", c.attn), ("SE(l+1)", c.se)]
    def place_experts(after):
        tail = after
        for i, d in enumerate(dispatches):
            e = sim.add(f"Expert{i}", comp(DEV), c.expert(k) / fc, [d, tail])
            experts.append(e)
            tail = e
        return tail
    if slot == 0:
        last_backbone = place_experts(last_backbone)
    for i, (label, dur) in enumerate(window):
        last_backbone = sim.add(label, comp(DEV), dur, [last_backbone])
        if slot == i + 1:
            last_backbone = place_experts(last_backbone)
    combines = []
    for i, e in enumerate(experts):
        combines.append(sim.add(f"A2A-C{i}", comm(DEV), c.a2a(k) / fc, [e]))
    deps = combines[:]
    deps.append(last_backbone)
    sim.add("Decode", comp(DEV), c.decode, deps)
    return sim

def build_pair_schedule(c, kind, strat, slot):
    k = routed_k(kind)
    name = strat[0]
    if name == "seq":
        return build_sequential(c, kind, k)
    if name == "pipe":
        return build_pipelined(c, kind, k, strat[1])
    if name == "overlap":
        return build_overlap(c, kind, k, slot, 1)
    if name == "overlap-pipe":
        return build_overlap(c, kind, k, slot, strat[1])
    raise ValueError(name)

def choose_expert_slot(c, kind, strat):
    best = (0, float("inf"))
    for slot in range(4):
        t = build_pair_schedule(c, kind, strat, slot).makespan()
        if t < best[1]:
            best = (slot, t)
    return best

# --- topo builders (new code) -----------------------------------------------

def build_sequential_topo(tc, kind, k):
    n = tc.n_devices()
    n_links = len(tc.a2a_inter_k1)
    sim = Sim()
    attn_m = []; enc = []
    for d in range(n):
        c = tc.per_device[d]
        attn_l = sim.add("Attn(l)", comp(d), c.attn, [])
        mlp_l = sim.add("MLP(l)", comp(d), c.mlp, [attn_l])
        a_m = sim.add("Attn(l+1)", comp(d), c.attn, [mlp_l])
        gate = sim.add("Gate", comp(d), c.gate, [a_m])
        e = sim.add("Encode", comp(d), c.encode, [gate])
        attn_m.append(a_m); enc.append(e)
    disp = []
    for d in range(n):
        disp.append(sim.add("A2A-D", comm(d), tc.a2a_intra(d, k), [enc[d]]))
    for node in range(n_links):
        deps = [enc[d] for d in tc.devices_of(node)]
        disp.append(sim.add("A2A-Dx", link(node), tc.a2a_inter(node, k), deps))
    experts = []
    for d in range(n):
        c = tc.per_device[d]
        experts.append(sim.add("Expert", comp(d), c.expert(k), disp))
    comb = []
    for d in range(n):
        comb.append(sim.add("A2A-C", comm(d), tc.a2a_intra(d, k), [experts[d]]))
    for node in range(n_links):
        deps = [experts[d] for d in tc.devices_of(node)]
        comb.append(sim.add("A2A-Cx", link(node), tc.a2a_inter(node, k), deps))
    for d in range(n):
        c = tc.per_device[d]
        deps = comb[:]
        if has_shared_expert(kind):
            se = sim.add("SE", comp(d), c.se, [attn_m[d]])
            deps.append(se)
        sim.add("Decode", comp(d), c.decode, deps)
    return sim

def build_pipelined_topo(tc, kind, k, chunks):
    n = tc.n_devices()
    n_links = len(tc.a2a_inter_k1)
    sim = Sim()
    attn_m = []; enc = []
    for d in range(n):
        c = tc.per_device[d]
        attn_l = sim.add("Attn(l)", comp(d), c.attn, [])
        mlp_l = sim.add("MLP(l)", comp(d), c.mlp, [attn_l])
        a_m = sim.add("Attn(l+1)", comp(d), c.attn, [mlp_l])
        gate = sim.add("Gate", comp(d), c.gate, [a_m])
        e = sim.add("Encode", comp(d), c.encode, [gate])
        attn_m.append(a_m); enc.append(e)
    fc = float(chunks)
    prev_d = [None] * n
    prev_x = [None] * n_links
    combines = []
    for i in range(chunks):
        disp_i = []
        for d in range(n):
            deps = [enc[d]]
            if prev_d[d] is not None:
                deps.append(prev_d[d])
            t = sim.add(f"A2A-D{i}", comm(d), tc.a2a_intra(d, k) / fc, deps)
            prev_d[d] = t
            disp_i.append(t)
        for node in range(n_links):
            deps = [enc[d] for d in tc.devices_of(node)]
            if prev_x[node] is not None:
                deps.append(prev_x[node])
            t = sim.add(f"A2A-Dx{i}", link(node), tc.a2a_inter(node, k) / fc, deps)
            prev_x[node] = t
            disp_i.append(t)
        experts_i = []
        for d in range(n):
            c = tc.per_device[d]
            experts_i.append(sim.add(f"Expert{i}", comp(d), c.expert(k) / fc, disp_i))
        for d in range(n):
            combines.append(sim.add(f"A2A-C{i}", comm(d), tc.a2a_intra(d, k) / fc,
                                    [experts_i[d]]))
        for node in range(n_links):
            deps = [experts_i[d] for d in tc.devices_of(node)]
            combines.append(sim.add(f"A2A-Cx{i}", link(node),
                                    tc.a2a_inter(node, k) / fc, deps))
    for d in range(n):
        c = tc.per_device[d]
        deps = combines[:]
        if has_shared_expert(kind):
            se = sim.add("SE", comp(d), c.se, [attn_m[d]])
            deps.append(se)
        sim.add("Decode", comp(d), c.decode, deps)
    return sim

def build_overlap_topo(tc, kind, k, slot, chunks):
    assert slot <= 3 and chunks >= 1
    n = tc.n_devices()
    n_links = len(tc.a2a_inter_k1)
    sim = Sim()
    attn_l_ids = []; enc = []
    for d in range(n):
        c = tc.per_device[d]
        attn_l = sim.add("Attn(l)", comp(d), c.attn, [])
        gate = sim.add("Gate", comp(d), c.gate, [attn_l])
        e = sim.add("Encode", comp(d), c.encode, [gate])
        attn_l_ids.append(attn_l); enc.append(e)
    fc = float(chunks)
    disp_chunks = []
    prev_d = [None] * n
    prev_x = [None] * n_links
    for i in range(chunks):
        disp_i = []
        for d in range(n):
            deps = [enc[d]]
            if prev_d[d] is not None:
                deps.append(prev_d[d])
            t = sim.add(f"A2A-D{i}", comm(d), tc.a2a_intra(d, k) / fc, deps)
            prev_d[d] = t
            disp_i.append(t)
        for node in range(n_links):
            deps = [enc[d] for d in tc.devices_of(node)]
            if prev_x[node] is not None:
                deps.append(prev_x[node])
            t = sim.add(f"A2A-Dx{i}", link(node), tc.a2a_inter(node, k) / fc, deps)
            prev_x[node] = t
            disp_i.append(t)
        disp_chunks.append(disp_i)
    last_backbone = [0] * n
    experts_by_dev = []
    for d in range(n):
        c = tc.per_device[d]
        dev_experts = []
        def place(after):
            tail = after
            for i, disp_i in enumerate(disp_chunks):
                deps = disp_i[:]
                deps.append(tail)
                e = sim.add(f"Expert{i}", comp(d), c.expert(k) / fc, deps)
                dev_experts.append(e)
                tail = e
            return tail
        tail = attn_l_ids[d]
        if slot == 0:
            tail = place(tail)
        window = [("MLP(l)", c.mlp), ("Attn(l+1)", c.attn), ("SE(l+1)", c.se)]
        for wi, (label, dur) in enumerate(window):
            tail = sim.add(label, comp(d), dur, [tail])
            if slot == wi + 1:
                tail = place(tail)
        last_backbone[d] = tail
        experts_by_dev.append(dev_experts)
    combines = []
    for i in range(chunks):
        for d in range(n):
            combines.append(sim.add(f"A2A-C{i}", comm(d), tc.a2a_intra(d, k) / fc,
                                    [experts_by_dev[d][i]]))
        for node in range(n_links):
            deps = [experts_by_dev[d][i] for d in tc.devices_of(node)]
            combines.append(sim.add(f"A2A-Cx{i}", link(node),
                                    tc.a2a_inter(node, k) / fc, deps))
    for d in range(n):
        c = tc.per_device[d]
        deps = combines[:]
        deps.append(last_backbone[d])
        sim.add("Decode", comp(d), c.decode, deps)
    return sim

def build_pair_schedule_topo(tc, kind, strat, slot):
    k = routed_k(kind)
    name = strat[0]
    if name == "seq":
        return build_sequential_topo(tc, kind, k)
    if name == "pipe":
        return build_pipelined_topo(tc, kind, k, strat[1])
    if name == "overlap":
        return build_overlap_topo(tc, kind, k, slot, 1)
    if name == "overlap-pipe":
        return build_overlap_topo(tc, kind, k, slot, strat[1])
    raise ValueError(name)

def choose_expert_slot_topo(tc, kind, strat):
    best = (0, float("inf"))
    for slot in range(4):
        t = build_pair_schedule_topo(tc, kind, strat, slot).makespan()
        if t < best[1]:
            best = (slot, t)
    return best
