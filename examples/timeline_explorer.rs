//! Render the Fig. 6 operator timelines for every architecture × strategy
//! on any hardware preset, plus the adaptive expert-slot search (Eq. 11).

use scmoe::cluster::Scenario;
use scmoe::coordinator::adaptive::{choose_expert_slot, eq11_objective};
use scmoe::coordinator::costs::{MoEKind, Strategy};
use scmoe::coordinator::schedule::build_pair_schedule;
use scmoe::coordinator::timeline;
use scmoe::report::efficiency::proxy_costs;
use scmoe::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let sc = Scenario::parse(&args.str_or("scenario", "pcie"))
        .unwrap_or(Scenario::PcieA30x8);
    let width = args.usize_or("width", 110);
    let c = proxy_costs(sc);
    println!("### {} (Fig. 6 reproduction) ###", sc.label());

    let rows: Vec<(&str, MoEKind, Strategy)> = vec![
        ("1. Standard top-2, sequential", MoEKind::Standard { k: 2 }, Strategy::Sequential),
        ("2. Standard top-2, pipelined", MoEKind::Standard { k: 2 },
         Strategy::Pipelined { chunks: 2 }),
        ("3. Shared-expert MoE", MoEKind::SharedExpert, Strategy::Pipelined { chunks: 1 }),
        ("4. ScMoE + overlapping", MoEKind::ScMoE { k: 1 }, Strategy::Overlap),
        ("5. ScMoE + overlapping + pipelining", MoEKind::ScMoE { k: 1 },
         Strategy::OverlapPipelined { chunks: 2 }),
    ];
    for (label, kind, strat) in rows {
        let slot = match strat {
            Strategy::Overlap | Strategy::OverlapPipelined { .. } => {
                choose_expert_slot(&c, kind, strat).0
            }
            _ => 0,
        };
        let s = build_pair_schedule(&c, kind, strat, slot);
        println!("\n--- {label} ---");
        print!("{}", timeline::render(&s.run(), width));
    }

    println!("\n### adaptive expert-slot search (ScMoE, Eq. 11) ###");
    let kind = MoEKind::ScMoE { k: 1 };
    for slot in 0..4 {
        let t = build_pair_schedule(&c, kind, Strategy::Overlap, slot).makespan();
        println!("slot {}: DES makespan {:.3}ms | Eq.11 objective {:.3}ms",
                 slot + 1, t * 1e3, eq11_objective(&c, kind, slot) * 1e3);
    }
    let (best, t) = choose_expert_slot(&c, kind, Strategy::Overlap);
    println!("chosen: slot {} ({:.3}ms)", best + 1, t * 1e3);
}
