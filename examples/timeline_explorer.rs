//! Render the Fig. 6 operator timelines for every architecture × strategy
//! on any hardware preset, plus the adaptive expert-slot search (Eq. 11).
//!
//! With `--fleet`, switch to the topology-aware multi-device DES: every
//! device of the preset gets its own compute/comm rows, inter-node
//! All-to-All phases appear on the shared `link[n]` rows, and the adaptive
//! slot is chosen per topology (compare presets with `--scenario`).
//!
//! With `--placement`, contrast *routed* All-to-All traffic under three
//! expert placements (block, affinity-packed, imbalance-skewed) against
//! the uniform byte-matrix model on a multi-node preset (default
//! `--scenario 4node-ib`): affinity packing a node-affine routing drives
//! the `link[n]` rows to zero-length phases.
//!
//! With `--skew`, contrast *load-true* expert compute under the balanced
//! block layout vs imbalance-skewed layouts: the hot devices' Expert
//! spans stretch by `load / mean` while the unloaded devices' spans
//! vanish, and the fleet barrier follows the hot prefix — the same rows
//! `scmoe report topo`'s load-skew study tabulates.
//!
//! With `--replace`, run the live re-placement study's drift scenario on
//! the 4-node IB preset: render the *migration step* (the block-layout
//! schedule with the measured-affinity `MigrationPlan`'s H2D transfers
//! overlapped on the `h2d[d]` rows), then the post-migration node-local
//! step, plus the cumulative static-vs-replace table and the regime-shift
//! policy comparison `scmoe report replace` tabulates.
//!
//! With `--serve`, run the open-loop serving study's mid-load cell
//! (`scmoe report serve` constants): print the serving loop's step log
//! (batch composition, queue depth, online migrations), render one mixed
//! prefill+decode step's fleet timeline, and compare the swept loads'
//! latency percentiles.
//!
//! With `--chaos`, run the chaos robustness study's fault scenarios on
//! the 4-node IB preset: render the straggler-perturbed fleet step next
//! to the clean one, the dropout recovery step (the failover migration
//! storm on the `h2d[d]` rows), and the robustness + C2R head-to-head
//! tables `scmoe report chaos` prints.
//!
//! With `--model`, run the whole-model pipeline study on the 4-node IB
//! preset: render one GPipe step's L-layer timeline (stage 1's layers on
//! their own engine rows, layer-l A2A overlapping layer-l±1 compute),
//! print the placement × schedule grid and the live break-even row with
//! source-side D2H pricing — the same cells `scmoe report model`
//! tabulates.
//!
//! In `--fleet` mode, `--critpath` redraws every span on the realized
//! critical path with `#` bars and prints the path's makespan
//! attribution (`analyze::critpath`), and `--export-trace PATH` writes
//! the ScMoE fleet timeline as Chrome-trace-event JSON for Perfetto /
//! `chrome://tracing` (`analyze::export`).
//!
//! `--chunks N` sets the pipeline depth of the chunked rows (default 2).
//! Every chunk pays its own launch latency, so deep chunking visibly
//! stops helping; in `--fleet` mode the chunked ScMoE timeline is also
//! rendered with MoNTA-style intra/inter staging and compared against
//! the phase-chained baseline.
//!
//! All schedules are built through the one construction API:
//! `ScheduleSpec::new(kind, strategy).build(&cost_model)`.

use std::collections::BTreeSet;

use scmoe::analyze::{attribute, chrome_trace, critical_path};
use scmoe::cluster::{ChaosSpec, Scenario};
use scmoe::coordinator::adaptive::eq11_objective;
use scmoe::coordinator::costs::{MoEKind, Strategy, TopoCosts};
use scmoe::coordinator::replace::{failover_placement, MigrationPlan,
                                  ReplacePolicy};
use scmoe::coordinator::schedule::ChunkPipelining;
use scmoe::coordinator::spec::ScheduleSpec;
use scmoe::coordinator::timeline;
use scmoe::moe::{AffinityEstimator, Placement};
use scmoe::report::chaos::{
    c2r_study_tables, c2r_uplink_fault, chaos_scenarios, run_chaos_cell,
    tail_stats, CHAOS_DROP_DEVICE, CHAOS_DROP_STEP,
};
use scmoe::coordinator::model::{build_model_sim, model_layer_costs,
                                PipelineSchedule, PlacementMode};
use scmoe::report::efficiency::{
    load_skew_study_rows, placement_study_rows, proxy_costs, topo_proxy_costs,
    xl_compute_costs, xl_topo_proxy_costs,
};
use scmoe::report::model_report::{
    model_config, model_grid_placements, model_spec, model_tables,
    run_model_cell, study_d2h_link, MODEL_LAYERS, MODEL_MICROBATCHES,
};
use scmoe::report::replace::{
    break_even_step, migration_marks, run_study, study_config, study_tables,
    STUDY_DRIFT_NOISE, STUDY_DRIFT_SEED, STUDY_SHIFT_DECAY, STUDY_SHIFT_NOISE,
    STUDY_SHIFT_SEED, STUDY_SHIFT_STEP, STUDY_TOKEN_BYTES,
};
use scmoe::report::serve_report::{
    run_serve_cell, serve_spec, SERVE_BUDGET, SERVE_DECODE_NOISE, SERVE_LOADS,
    SERVE_PREFILL_NOISE, SERVE_SLO, SERVE_TOKEN_BYTES, SERVE_TRAFFIC_SEED,
};
use scmoe::moe::phase_affine_routing;
use scmoe::serve::BatchPolicy;
use scmoe::simtime::makespan;
use scmoe::util::cli::Args;

fn main() {
    let args = Args::from_env();
    if args.flag("serve") {
        serve_mode(args.usize_or("width", 110));
        return;
    }
    if args.flag("replace") {
        replace_mode(args.usize_or("width", 110));
        return;
    }
    if args.flag("chaos") {
        chaos_mode(args.usize_or("width", 110));
        return;
    }
    if args.flag("model") {
        model_mode(args.usize_or("width", 110));
        return;
    }
    if args.flag("placement") || args.flag("skew") {
        let sc = Scenario::parse(&args.str_or("scenario", "4node-ib"))
            .unwrap_or(Scenario::FourNodeA800IBx32);
        // same defaults as `scmoe report topo`'s routed studies so the
        // rendered timelines match the tables row for row
        let (width, tokens, seed) = (args.usize_or("width", 110),
                                     args.usize_or("tokens", 640),
                                     args.u64_or("seed", 7));
        if args.flag("skew") {
            skew_mode(sc, width, tokens, seed);
        } else {
            placement_mode(sc, width, tokens, seed);
        }
        return;
    }
    let sc = Scenario::parse(&args.str_or("scenario", "pcie"))
        .unwrap_or(Scenario::PcieA30x8);
    let width = args.usize_or("width", 110);
    let chunks = args.usize_or("chunks", 2).max(1);
    if args.flag("fleet") {
        fleet_mode(sc, width, chunks, args.flag("critpath"),
                   args.str_opt("export-trace"));
        return;
    }
    let c = proxy_costs(sc);
    println!("### {} (Fig. 6 reproduction, {chunks} chunks) ###", sc.label());

    let rows: Vec<(&str, MoEKind, Strategy)> = vec![
        ("1. Standard top-2, sequential", MoEKind::Standard { k: 2 }, Strategy::Sequential),
        ("2. Standard top-2, pipelined", MoEKind::Standard { k: 2 },
         Strategy::Pipelined { chunks }),
        ("3. Shared-expert MoE", MoEKind::SharedExpert, Strategy::Pipelined { chunks: 1 }),
        ("4. ScMoE + overlapping", MoEKind::ScMoE { k: 1 }, Strategy::Overlap),
        ("5. ScMoE + overlapping + pipelining", MoEKind::ScMoE { k: 1 },
         Strategy::OverlapPipelined { chunks }),
    ];
    for (label, kind, strat) in rows {
        let s = ScheduleSpec::new(kind, strat).adaptive().build(&c);
        println!("\n--- {label} ---");
        print!("{}", timeline::render(&s.run(), width));
    }

    println!("\n### adaptive expert-slot search (ScMoE, Eq. 11) ###");
    let kind = MoEKind::ScMoE { k: 1 };
    let spec = ScheduleSpec::new(kind, Strategy::Overlap);
    for slot in 0..4 {
        let t = spec.with_slot(slot).build(&c).makespan();
        println!("slot {}: DES makespan {:.3}ms | Eq.11 objective {:.3}ms",
                 slot + 1, t * 1e3, eq11_objective(&c, kind, slot) * 1e3);
    }
    let (best, t) = spec.choose_slot(&c);
    println!("chosen: slot {} ({:.3}ms)", best + 1, t * 1e3);
}

/// Render a fleet timeline; with `critpath` the realized critical path's
/// spans are drawn with `#` bars and its makespan attribution printed.
fn render_fleet(sim: &scmoe::simtime::Sim, width: usize, critpath: bool)
                -> Vec<scmoe::simtime::Span> {
    if !critpath {
        let spans = sim.run();
        print!("{}", timeline::render(&spans, width));
        return spans;
    }
    let run = sim.run_traced();
    let crit: BTreeSet<usize> = critical_path(&run).into_iter().collect();
    print!("{}", timeline::render_marked(&run.spans, width, &crit));
    let a = attribute(&run);
    println!("critical path: {} tasks | backbone {:.3}ms  expert {:.3}ms  \
              dispatch {:.3}ms  combine {:.3}ms  migr {:.3}ms",
             crit.len(), a.backbone * 1e3, a.expert * 1e3, a.dispatch * 1e3,
             a.combine * 1e3, a.migration * 1e3);
    run.spans
}

fn fleet_mode(sc: Scenario, width: usize, chunks: usize, critpath: bool,
              export_trace: Option<&str>) {
    let tc = topo_proxy_costs(sc);
    println!("### {} — topology-aware fleet ({} devices, {} nodes) ###",
             sc.label(), tc.n_devices(), tc.n_nodes());
    let dpn = tc.n_devices() / tc.n_nodes();
    let kind = MoEKind::ScMoE { k: 1 };
    let base = ScheduleSpec::new(MoEKind::Standard { k: 2 },
                                 Strategy::Sequential)
        .build(&tc);
    println!("\n--- standard top-2, sequential (fleet) ---");
    let base_spans = render_fleet(&base.sim, width, critpath);
    let ovl = ScheduleSpec::new(kind, Strategy::Overlap);
    let (slot, _) = ovl.choose_slot(&tc);
    let sched = ovl.with_slot(slot).build(&tc);
    println!("\n--- ScMoE overlapping (fleet, adaptive slot {}) ---", slot + 1);
    let spans = render_fleet(&sched.sim, width, critpath);
    println!("\nspeedup: {:.2}x", makespan(&base_spans) / makespan(&spans));
    if let Some(path) = export_trace {
        let run = sched.sim.run_traced();
        let json = chrome_trace(&sched.sim, &run, dpn);
        std::fs::write(path, json + "\n").expect("write trace file");
        println!("wrote Chrome trace of the ScMoE fleet timeline to {path} \
                  (open in Perfetto / chrome://tracing)");
    }

    if chunks > 1 {
        // chunked MoE stream: every chunk pays its own α; the uplink task
        // of chunk i is staged behind the node's intra tasks and overlaps
        // chunk i+1's intra phase (MoNTA-style)
        let ospec = ScheduleSpec::new(kind, Strategy::OverlapPipelined { chunks });
        let (cslot, staged) = ospec.choose_slot(&tc);
        let cspans = ospec.with_slot(cslot).build(&tc).run();
        println!("\n--- ScMoE overlap + {chunks}-chunk pipeline \
                  (staged, slot {}) ---", cslot + 1);
        print!("{}", timeline::render(&cspans, width));
        let chained = ospec
            .with_slot(cslot)
            .with_pipelining(ChunkPipelining::PhaseChained)
            .build(&tc)
            .makespan();
        println!("\nstaged {:.3}ms vs phase-chained {:.3}ms \
                  (intra/inter overlap saves {:.0}us)",
                 staged * 1e3, chained * 1e3, (chained - staged) * 1e6);
    }

    // The slot choice is workload-dependent: the light Swin payload agrees
    // on one slot everywhere, while the comm-heavy GPT3-XL payload makes
    // the optimum diverge across topologies.
    println!("\n### adaptive slot per topology preset ###");
    println!("{:<18} {:>8} {:>8} {:>14}", "preset", "SwinV2", "GPT3-XL", "XL makespan");
    let ovl = ScheduleSpec::new(kind, Strategy::Overlap);
    for p in Scenario::extended() {
        let (s_swin, _) = ovl.choose_slot(&topo_proxy_costs(p));
        let (s_xl, m_xl) = ovl.choose_slot(&xl_topo_proxy_costs(p));
        println!("{:<18} {:>8} {:>8} {:>12.3}ms",
                 p.label(), s_swin + 1, s_xl + 1, m_xl * 1e3);
    }
}

/// Contrast uniform vs. routed All-to-All traffic under the placement
/// study's rows on one preset (GPT3-XL payload, node-affine routing) —
/// the same rows `scmoe report topo` tabulates, rendered as timelines.
fn placement_mode(sc: Scenario, width: usize, tokens_per_device: usize,
                  seed: u64) {
    let topo = sc.topology();
    let kind = MoEKind::ScMoE { k: 1 };
    println!("### {} — routed placement timelines ({} devices, {} nodes, \
              seed {seed}) ###",
             sc.label(), topo.n_devices, topo.n_nodes());
    if topo.n_nodes() < 2 {
        println!("(single-node preset: every placement is already node-local; \
                  try --scenario 4node-ib)");
    }
    let rows = placement_study_rows(&topo, tokens_per_device, seed);
    let ovl = ScheduleSpec::new(kind, Strategy::Overlap);
    let mut makespans = Vec::new();
    for (label, tc) in &rows {
        let (slot, _) = ovl.choose_slot(tc);
        let spans = ovl.with_slot(slot).build(tc).run();
        println!("\n--- ScMoE overlap, {label} (adaptive slot {}) ---", slot + 1);
        print!("{}", timeline::render(&spans, width));
        makespans.push(makespan(&spans));
    }
    let vs_uniform: Vec<String> = rows.iter()
        .zip(&makespans)
        .skip(1)
        .map(|((label, _), m)| format!("{label} {:.2}x", makespans[0] / m))
        .collect();
    println!("\noverlap speedup vs uniform: {}", vs_uniform.join(" | "));
}

/// Render the live re-placement study: the migration step (H2D rows
/// overlapped behind the block-layout step) and the post-migration
/// node-local step, plus the cumulative and policy tables of
/// `scmoe report replace`.
fn replace_mode(width: usize) {
    let sc = Scenario::FourNodeA800IBx32;
    let topo = sc.topology();
    let base = xl_compute_costs();
    // the exact configuration the drift study runs (same spec, expert
    // bytes, H2D link, counting estimator), so the rendered timelines
    // can never diverge from the tables printed below
    let cfg = study_config(ReplacePolicy::BreakEven, 1.0);
    let spec = cfg.spec;
    println!("### {} — live re-placement timelines ({} devices, {} nodes) ###",
             sc.label(), topo.n_devices, topo.n_nodes());

    // the drift scenario's migration step, reconstructed: observe step
    // 0's table, pack the measured affinity, overlap the H2D transfers
    let tables = study_tables(STUDY_DRIFT_NOISE, STUDY_DRIFT_SEED, None);
    let block = Placement::new(32, 32);
    let mut est = AffinityEstimator::ewma(32, topo.n_nodes(), cfg.decay);
    est.observe(&tables[0], topo.n_devices, topo.devices_per_node);
    let measured = est.packed(topo.n_devices, topo.devices_per_node);
    let plan = MigrationPlan::between(&block, &measured, cfg.bytes_per_expert);
    let tc = TopoCosts::from_routing(&base, &topo, &tables[0], &block,
                                     STUDY_TOKEN_BYTES);
    let mut sched = spec.build(&tc);
    let base_ms = sched.makespan();
    plan.add_h2d_tasks(&mut sched.sim, &cfg.h2d);
    let spans = sched.run();
    println!("\n--- migration step: uniform block layout + {} expert \
              transfers on h2d rows ---", plan.moves.len());
    print!("{}", timeline::render(&spans, width));
    println!("step stretches {:.3}ms -> {:.3}ms: the H2D engines outlast \
              the step's compute",
             base_ms * 1e3, makespan(&spans) * 1e3);

    let tc_after = TopoCosts::from_routing(&base, &topo, &tables[1],
                                           &measured, STUDY_TOKEN_BYTES);
    let after = spec.build(&tc_after);
    println!("\n--- post-migration step: measured-affinity layout \
              (node-local routes) ---");
    print!("{}", timeline::render(&after.run(), width));

    // the cumulative table + policy comparison, same runs as the report
    let static_run = run_study(&tables, ReplacePolicy::Never, 1.0);
    let replace_run = run_study(&tables, ReplacePolicy::BreakEven, 1.0);
    println!("\nstatic-uniform total {:.3}ms vs migrate-then-run {:.3}ms \
              over {} steps ({:.2}x)",
             static_run.total * 1e3, replace_run.total * 1e3,
             static_run.steps.len(),
             static_run.total / replace_run.total);
    match break_even_step(&static_run, &replace_run) {
        Some(n) => println!("break-even: replacing pulls ahead from step \
                             {n} on"),
        None => println!("break-even: not reached"),
    }

    println!("\n### regime shift at step {} (noise {:.0}%, EWMA decay {}) ###",
             STUDY_SHIFT_STEP, STUDY_SHIFT_NOISE * 100.0, STUDY_SHIFT_DECAY);
    let shifted = study_tables(STUDY_SHIFT_NOISE, STUDY_SHIFT_SEED,
                               Some(STUDY_SHIFT_STEP));
    for policy in [ReplacePolicy::Never, ReplacePolicy::EveryK { k: 1 },
                   ReplacePolicy::BreakEven] {
        let run = run_study(&shifted, policy, STUDY_SHIFT_DECAY);
        println!("{:<12} total {:>9.3}ms  migrations {:>2}  {}",
                 policy.label(), run.total * 1e3, run.migrations,
                 migration_marks(&run));
    }
}

/// Render the open-loop serving study's mid-load cell: the serving
/// loop's step log (batch composition, queue depth, online migrations),
/// one mixed prefill+decode step's fleet timeline, and the swept loads'
/// latency percentiles — the same cells `scmoe report serve` tabulates.
fn serve_mode(width: usize) {
    let sc = Scenario::FourNodeA800IBx32;
    let topo = sc.topology();
    let base = xl_compute_costs();
    let budget = BatchPolicy::TokenBudget { budget: SERVE_BUDGET };
    println!("### {} — open-loop serving timelines ({} devices, {} nodes) ###",
             sc.label(), topo.n_devices, topo.n_nodes());

    let rate = SERVE_LOADS[1];
    let out = run_serve_cell(rate, Strategy::Sequential, budget,
                             ReplacePolicy::BreakEven);
    println!("\n--- step log at {rate:.0} req/s (seq, break-even replace; \
              first 12 steps) ---");
    println!("{:>4} {:>10} {:>8} {:>7} {:>6} {:>10} {:>5} {:>4}",
             "step", "start", "prefill", "decode", "queue", "makespan",
             "migr", "done");
    for st in out.steps.iter().take(12) {
        println!("{:>4} {:>9.1}ms {:>5}/{:<2} {:>7} {:>6} {:>9.3}ms {:>5} {:>4}",
                 st.step, st.start * 1e3, st.prefill_tokens, st.prefills,
                 st.decodes, st.queued, st.makespan * 1e3,
                 if st.migrated { "M" } else { "." }, st.completed);
    }
    println!("({} steps total, {} migration(s), busy {:.1}ms of {:.1}ms)",
             out.steps.len(), out.migrations, out.busy * 1e3,
             out.total_time * 1e3);

    // render the busiest mixed step, replayed from a static-placement run
    // (Never policy keeps the block layout, so the replay is exact)
    let static_out = run_serve_cell(rate, Strategy::Sequential, budget,
                                    ReplacePolicy::Never);
    let mixed = static_out
        .steps
        .iter()
        .filter(|s| s.prefills > 0 && s.decodes > 0)
        .max_by_key(|s| s.prefill_tokens + s.decode_tokens)
        .expect("mid load mixes prefill and decode");
    let rt = phase_affine_routing(topo.n_devices, topo.devices_per_node, 32,
                                  mixed.prefill_tokens, mixed.decode_tokens,
                                  0, SERVE_PREFILL_NOISE, SERVE_DECODE_NOISE,
                                  SERVE_TRAFFIC_SEED + mixed.step as u64);
    let tc = TopoCosts::from_routing(&base, &topo, &rt, &Placement::new(32, 32),
                                     SERVE_TOKEN_BYTES);
    let sched = serve_spec(Strategy::Sequential).build(&tc);
    println!("\n--- step {}: {} prompt tokens ({} prefills) + {} decode \
              tokens ({} requests) ---",
             mixed.step, mixed.prefill_tokens, mixed.prefills,
             mixed.decode_tokens, mixed.decodes);
    print!("{}", timeline::render(&sched.run(), width));

    println!("\n--- swept loads (seq, break-even replace) ---");
    for rate in SERVE_LOADS {
        let o = run_serve_cell(rate, Strategy::Sequential, budget,
                               ReplacePolicy::BreakEven);
        println!("{:>4.0} req/s: p50 {:>8.3}ms  p99 {:>8.3}ms  \
                  throughput {:>6.1} req/s  goodput {:>6.1} req/s",
                 rate, o.p50() * 1e3, o.p99() * 1e3, o.throughput(),
                 o.goodput(SERVE_SLO));
    }
}

/// Render the chaos study's fault scenarios: the straggler-perturbed
/// fleet step (slow devices' Compute rows visibly stretched against the
/// clean step), the dropout recovery step (the failover migration storm
/// on the `h2d[d]` rows), and the robustness + C2R tables
/// `scmoe report chaos` tabulates.
fn chaos_mode(width: usize) {
    let sc = Scenario::FourNodeA800IBx32;
    let topo = sc.topology();
    let base = xl_compute_costs();
    // same configuration as the chaos study's cells, so the rendered
    // steps match the tables printed below
    let cfg = study_config(ReplacePolicy::Never, 1.0);
    let spec = cfg.spec;
    println!("### {} — chaos timelines ({} devices, {} nodes) ###",
             sc.label(), topo.n_devices, topo.n_nodes());

    let tables = study_tables(STUDY_DRIFT_NOISE, STUDY_DRIFT_SEED, None);
    let block = Placement::new(32, 32);
    let scenarios = chaos_scenarios();

    // the stragglers scenario's step 0: seeded jitter plus two persistent
    // stragglers stretch the slow devices' rows and the fleet barrier
    let straggle = &scenarios[0].1;
    let clean_tc = TopoCosts::from_routing(&base, &topo, &tables[0], &block,
                                           STUDY_TOKEN_BYTES);
    let clean_ms = spec.build(&clean_tc).makespan();
    let ptopo = straggle.perturb(&topo, 0);
    let tc = TopoCosts::from_routing(&base, &ptopo, &tables[0], &block,
                                     STUDY_TOKEN_BYTES);
    let spans = spec.build(&tc).run();
    println!("\n--- stragglers, step 0: 10% jitter + d3 1.5x + d17 2.0x ---");
    print!("{}", timeline::render(&spans, width));
    println!("clean step {:.3}ms -> perturbed {:.3}ms ({:.2}x): the fleet \
              barrier tracks the slowest straggler",
             clean_ms * 1e3, makespan(&spans) * 1e3,
             makespan(&spans) / clean_ms);

    // the dropout scenario's recovery step: the failed device's expert
    // fails over to the least-loaded survivor, and the migration storm
    // overlaps the step on the h2d rows
    let failover = failover_placement(&block, CHAOS_DROP_DEVICE);
    let plan = MigrationPlan::between(&block, &failover, cfg.bytes_per_expert);
    let tc = TopoCosts::from_routing(&base, &topo, &tables[CHAOS_DROP_STEP],
                                     &block, STUDY_TOKEN_BYTES);
    let mut sched = spec.build(&tc);
    let base_ms = sched.makespan();
    plan.add_h2d_tasks(&mut sched.sim, &cfg.h2d);
    let spans = sched.run();
    println!("\n--- dropout recovery, step {}: device {} fails, {} expert \
              transfer(s) on h2d rows ---",
             CHAOS_DROP_STEP, CHAOS_DROP_DEVICE, plan.moves.len());
    print!("{}", timeline::render(&spans, width));
    println!("recovery step stretches {:.3}ms -> {:.3}ms: the failover \
              storm outlasts the step's compute",
             base_ms * 1e3, makespan(&spans) * 1e3);

    // the robustness table, block placement, sequential schedule — the
    // same cells `scmoe report chaos` prints in its full grid
    println!("\n### robustness (block placement, seq) ###");
    println!("{:<14} {:<11} {:>10} {:>10} {:>6} {:>11} {:>4}",
             "scenario", "policy", "median", "p99", "amp", "total", "mig");
    let mut rows = vec![("clean", ChaosSpec::clean(0))];
    rows.extend(scenarios);
    for (name, chaos) in &rows {
        for policy in [ReplacePolicy::Never, ReplacePolicy::BreakEven] {
            let out = run_chaos_cell(&tables, &block, Strategy::Sequential, 0,
                                     policy, chaos);
            let (med, p99, amp) = tail_stats(&out);
            println!("{:<14} {:<11} {:>8.3}ms {:>8.3}ms {:>5.2}x {:>9.3}ms \
                      {:>4}",
                     name, policy.label(), med * 1e3, p99 * 1e3, amp,
                     out.total * 1e3, out.migrations);
        }
    }

    println!("\n### C2R bounded fanout under a persistent uplink fault ###");
    let fault = c2r_uplink_fault();
    for (name, constrained) in [("affine", false), ("c2r", true)] {
        let tbl = c2r_study_tables(constrained);
        let init = Placement::affinity_packed(&tbl[0], 32, 8);
        let clean = run_chaos_cell(&tbl, &init, Strategy::Sequential, 0,
                                   ReplacePolicy::Never, &ChaosSpec::clean(0));
        let deg = run_chaos_cell(&tbl, &init, Strategy::Sequential, 0,
                                 ReplacePolicy::Never, &fault);
        println!("{:<7} clean {:>9.3}ms  degraded {:>9.3}ms ({:.2}x)",
                 name, clean.total * 1e3, deg.total * 1e3,
                 deg.total / clean.total);
    }
    println!("collaboration-constrained routes never leave their node, so \
              the uplink fault cannot touch them");
}

/// Render the whole-model pipeline study: one GPipe step's L-layer
/// timeline under the cross-layer placements (stage 1's layers live on
/// their own engine rows), the placement × schedule grid at the
/// pipelined microbatch count, and the live break-even row — the same
/// cells `scmoe report model` tabulates.
fn model_mode(width: usize) {
    let sc = Scenario::FourNodeA800IBx32;
    let topo = sc.topology();
    let base = xl_compute_costs();
    println!("### {} — whole-model pipeline timelines ({} devices, \
              {} nodes) ###",
             sc.label(), topo.n_devices, topo.n_nodes());

    let tables = model_tables();
    let (per, cross) = model_grid_placements(&tables[0]);
    let block: Vec<Placement> = (0..MODEL_LAYERS)
        .map(|_| Placement::new(32, 32))
        .collect();

    // step 0 under the cross-layer placements, GPipe at the study's
    // microbatch count: stage 1's layers land on compute/comm rows 32+
    let spec = model_spec(MODEL_MICROBATCHES, PipelineSchedule::GPipe);
    let costs = model_layer_costs(&base, &topo, STUDY_TOKEN_BYTES,
                                  &tables[0], &cross, MODEL_MICROBATCHES);
    let (sim, _) = build_model_sim(&spec, &costs, topo.n_devices,
                                   topo.n_nodes());
    println!("\n--- step 0: {} layers x {} microbatches, GPipe, \
              cross-layer placements ---",
             MODEL_LAYERS, MODEL_MICROBATCHES);
    print!("{}", timeline::render(&sim.run(), width));

    println!("\n--- total {}-step makespan at m = {} ---",
             tables.len(), MODEL_MICROBATCHES);
    for schedule in [PipelineSchedule::LayerSequential,
                     PipelineSchedule::GPipe, PipelineSchedule::OneFOneB] {
        for (name, initial) in [("block", &block), ("per-layer", &per),
                                ("cross-layer", &cross)] {
            let cfg = model_config(MODEL_MICROBATCHES, schedule,
                                   ReplacePolicy::Never,
                                   PlacementMode::PerLayer, None);
            let out = run_model_cell(&tables, initial, &cfg);
            println!("{:<10} {:<12} total {:>9.3}ms",
                     schedule.label(), name, out.total * 1e3);
        }
    }

    let cfg = model_config(MODEL_MICROBATCHES, PipelineSchedule::GPipe,
                           ReplacePolicy::BreakEven,
                           PlacementMode::CrossLayer,
                           Some(study_d2h_link()));
    let out = run_model_cell(&tables, &block, &cfg);
    println!("\nlive (block start, break-even, cross-layer candidates, \
              D2H-priced): total {:.3}ms, {} migration(s)",
             out.total * 1e3, out.migrations);
    for st in &out.steps {
        println!("  step {}{} makespan {:>9.3}ms{}",
                 st.step, if st.migrated { "*" } else { " " },
                 st.makespan * 1e3,
                 if st.migrated {
                     format!(" (d2h+h2d {:.3}ms)", st.migration_time * 1e3)
                 } else {
                     String::new()
                 });
    }
}

/// Render the load-skew study's rows as fleet timelines: the balanced
/// block layout vs imbalance-skewed layouts, with load-true Expert spans
/// (hot devices stretched by `load / mean`, unloaded devices at zero).
/// The load-naive makespan (the pre-redesign model) is printed next to
/// each row to show what the balanced-capacity-batch assumption hid.
fn skew_mode(sc: Scenario, width: usize, tokens_per_device: usize, seed: u64) {
    let topo = sc.topology();
    let kind = MoEKind::ScMoE { k: 1 };
    println!("### {} — load-skew timelines ({} devices, seed {seed}) ###",
             sc.label(), topo.n_devices);
    let rows = load_skew_study_rows(&topo, tokens_per_device, seed);
    let ovl = ScheduleSpec::new(kind, Strategy::Overlap);
    for (label, tc) in &rows {
        let imb = tc.expert_load.as_ref().map_or(1.0, |l| l.imbalance());
        let (slot, m_true) = ovl.choose_slot(tc);
        let spans = ovl.with_slot(slot).build(tc).run();
        let mut naive = tc.clone();
        naive.expert_load = None;
        let (_, m_naive) = ovl.choose_slot(&naive);
        println!("\n--- ScMoE overlap, {label} (load imbalance {imb:.2}x, \
                  slot {}) ---", slot + 1);
        print!("{}", timeline::render(&spans, width));
        println!("load-true {:.3}ms vs load-naive {:.3}ms (+{:.0}us hidden \
                  by the balanced-batch assumption)",
                 m_true * 1e3, m_naive * 1e3, (m_true - m_naive) * 1e6);
    }
}
