//! Quickstart: load the AOT artifacts, run one MoE layer through the Rust
//! data plane, and compare the ScMoE overlap schedule against the standard
//! top-2 baseline on a calibrated hardware preset.
//!
//!   make artifacts && cargo run --release --example quickstart

use std::path::Path;
use std::sync::Arc;

use scmoe::cluster::Scenario;
use scmoe::coordinator::costs::{MoEKind, Strategy};
use scmoe::coordinator::spec::ScheduleSpec;
use scmoe::coordinator::timeline;
use scmoe::moe::{decode, encode, RoutingTable};
use scmoe::report::efficiency::proxy_costs;
use scmoe::runtime::{Engine, HostTensor};

fn main() -> anyhow::Result<()> {
    // --- 1. real numerics: gate -> encode -> experts -> decode on PJRT ---
    let root = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/ops_tiny"));
    anyhow::ensure!(root.join("manifest.json").exists(),
                    "run `make artifacts` first");
    let engine = Arc::new(Engine::cpu()?);
    let set = engine.open(root)?;
    let m = &set.manifest;
    let (t, d, e) = (m.tokens, m.config.d_model, m.config.n_experts);
    let k = 1;
    let cap = m.capacities[&k];
    println!("ops artifacts: {} tokens, d_model {}, {} experts, capacity {}",
             t, d, e, cap);

    let w = set.get("ops_init")?.run(&[HostTensor::scalar_i32(0)])?;
    let x = HostTensor::f32(vec![t, d],
                            (0..t * d).map(|i| ((i % 89) as f32 / 89.0) - 0.5).collect());
    let g = set.get("gate_op_k1")?.run(&[x.clone(), w[0].clone(), w[1].clone(),
                                         w[10].clone()])?;
    let table = RoutingTable::build(g[1].as_i32()?, g[2].as_f32()?, t, k, e, cap);
    println!("routing: kept {} / dropped {} | imbalance {:.2}",
             table.kept(), table.dropped, table.imbalance());

    let enc = encode(&table, g[0].as_f32()?, d);
    let ye = set.get(&format!("experts_op_c{cap}"))?.run(&[
        HostTensor::f32(vec![e, cap, d], enc),
        w[11].clone(), w[12].clone(), w[13].clone(), w[14].clone()])?;
    let y = decode(&table, ye[0].as_f32()?, d);
    println!("MoE output: {} tokens x {} dims (first = {:.4})", t, d, y[0]);

    // --- 2. the paper's schedule, on the PCIe preset ---
    let costs = proxy_costs(Scenario::PcieA30x8);
    println!("\n=== standard top-2 MoE (sequential) ===");
    let base = ScheduleSpec::new(MoEKind::Standard { k: 2 },
                                 Strategy::Sequential)
        .build(&costs);
    print!("{}", timeline::render(&base.run(), 100));
    println!("\n=== ScMoE with overlapping expert parallelism ===");
    let sc = ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, Strategy::Overlap)
        .adaptive()
        .build(&costs);
    print!("{}", timeline::render(&sc.run(), 100));
    println!("\nspeedup on 8xA30-PCIe: {:.2}x (paper Table 2: 1.66x inference)",
             base.makespan() / sc.makespan());
    Ok(())
}
