//! Real expert-parallel inference: worker threads own expert shards and
//! execute compiled expert HLO; All-to-All latencies are injected from the
//! calibrated link models; the ScMoE overlap genuinely hides them behind
//! backbone compute. Compares wall-clock of overlap vs sequential and
//! verifies numerics against the fused single-HLO oracle.

use std::path::Path;
use std::sync::Arc;

use scmoe::cluster::LinkModel;
use scmoe::coordinator::costs::{MoEKind, Strategy};
use scmoe::coordinator::exec::{run_pair_real, Cluster};
use scmoe::coordinator::spec::ScheduleSpec;
use scmoe::runtime::{Engine, HostTensor};
use scmoe::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let root = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/ops_tiny"));
    anyhow::ensure!(root.join("manifest.json").exists(), "run `make artifacts` first");
    let engine = Arc::new(Engine::cpu()?);
    let set = engine.open(root)?;
    let m = &set.manifest;
    let (t, d) = (m.tokens, m.config.d_model);
    let n_dev = args.usize_or("devices", 4);
    let k = 1;
    println!("spawning {} device workers ({} experts each)...",
             n_dev, m.config.n_experts / n_dev);
    let cluster = Cluster::spawn(&set, n_dev, k)?;

    let x = HostTensor::f32(vec![t, d],
                            (0..t * d).map(|i| ((i % 61) as f32 / 61.0) - 0.5).collect());
    // a deliberately slow link so the schedule difference is visible
    let link = LinkModel::new(0.0, args.f64_or("beta", 40e6));

    let seq_spec = ScheduleSpec::new(MoEKind::ScMoE { k }, Strategy::Sequential);
    let ovl_spec = ScheduleSpec::new(MoEKind::ScMoE { k }, Strategy::Overlap);
    let reps = args.usize_or("reps", 3);
    let mut t_seq = Vec::new();
    let mut t_ovl = Vec::new();
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        let (y_seq, _) = run_pair_real(&set, &cluster, &x, &seq_spec, None, link, 1.0, 2)?;
        t_seq.push(t0.elapsed().as_secs_f64());
        let t0 = std::time::Instant::now();
        let (y_ovl, spans) = run_pair_real(&set, &cluster, &x, &ovl_spec, None, link, 1.0, 2)?;
        t_ovl.push(t0.elapsed().as_secs_f64());
        // numerics must be identical
        for (a, b) in y_seq.iter().zip(&y_ovl) {
            assert!((a - b).abs() < 1e-5);
        }
        if t_ovl.len() == 1 {
            println!("\noverlap run spans:");
            for s in &spans {
                println!("  {:<14} {:>8.1}ms .. {:>8.1}ms", s.label,
                         s.start * 1e3, s.end * 1e3);
            }
        }
    }
    t_seq.sort_by(|a, b| a.total_cmp(b));
    t_ovl.sort_by(|a, b| a.total_cmp(b));
    println!("\nsequential: {:.1}ms | ScMoE overlap: {:.1}ms | speedup {:.2}x",
             t_seq[reps / 2] * 1e3, t_ovl[reps / 2] * 1e3,
             t_seq[reps / 2] / t_ovl[reps / 2]);
    println!("(numerics verified identical between both strategies)");
    Ok(())
}
