//! End-to-end driver: pre-train a GPT-MoE (ScMoE architecture) on the
//! bundled corpus entirely through the Rust runtime — Python is not on the
//! path. Logs the loss curve to reports/e2e_loss.csv and records the run
//! for EXPERIMENTS.md.
//!
//!   # tiny (default, a few minutes on one CPU core):
//!   cargo run --release --example train_gpt_moe -- --steps 200
//!   # the ~100M-class config (build artifacts first):
//!   cd python && python -m compile.aot --profile quality --arch scmoe \
//!       --preset e2e --out-root ../artifacts
//!   cargo run --release --example train_gpt_moe -- --preset e2e --steps 300

use std::path::PathBuf;
use std::sync::Arc;

use scmoe::runtime::Engine;
use scmoe::train::{TrainOptions, Trainer};
use scmoe::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let arch = args.str_or("arch", "scmoe");
    let preset = args.str_or("preset", "micro");
    let steps = args.usize_or("steps", 200);
    let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        .join(format!("quality_{arch}_{preset}"));
    anyhow::ensure!(dir.join("manifest.json").exists(),
                    "artifacts missing: {} (see header comment)", dir.display());

    let engine = Arc::new(Engine::cpu()?);
    let set = engine.open(&dir)?;
    println!("=== e2e training: {} / {} ===", arch, preset);
    println!("params: {} ({:.1}M) | task {} | batch {} x seq {}",
             set.manifest.param_count,
             set.manifest.param_count as f64 / 1e6,
             set.manifest.config.task,
             set.manifest.config.batch_size,
             set.manifest.config.seq_len);

    let mut tr = Trainer::new(&set, 0)?;
    let before = tr.evaluate(4)?;
    println!("before training: eval loss {:.4} (ppl {:.1})", before.loss, before.ppl);

    let opts = TrainOptions {
        steps,
        eval_every: (steps / 4).max(1),
        eval_batches: 4,
        log_csv: Some(PathBuf::from("reports/e2e_loss.csv")),
        stats_csv: Some(PathBuf::from("reports/e2e_stats.csv")),
        verbose: true,
        seed: 0,
    };
    tr.run(&opts)?;

    let after = tr.evaluate(8)?;
    let tokens_per_step = set.manifest.config.tokens_per_batch();
    let total_secs: f64 = tr.records.iter().map(|r| r.secs).sum();
    println!("\n=== run summary ===");
    println!("steps: {steps} | tokens/step: {tokens_per_step}");
    println!("eval loss: {:.4} -> {:.4} (ppl {:.1} -> {:.1})",
             before.loss, after.loss, before.ppl, after.ppl);
    println!("throughput: {:.0} tokens/s ({:.2} s/step)",
             (steps * tokens_per_step) as f64 / total_secs, total_secs / steps as f64);
    println!("loss curve: reports/e2e_loss.csv | Fig.11 stats: reports/e2e_stats.csv");
    anyhow::ensure!(after.loss < before.loss, "training must reduce loss");
    Ok(())
}
