//! Memory-limited inference with expert offloading (§3.3): expert
//! selections come from a *real* forward pass of the ScMoE artifacts, and
//! the three migration policies are compared on latency + peak memory.

use std::path::Path;
use std::sync::Arc;

use scmoe::offload::{simulate_decode, Policy};
use scmoe::report::offload_report::gpt2_moe_medium;
use scmoe::runtime::{Engine, HostTensor};
use scmoe::util::cli::Args;
use scmoe::util::stats::{fmt_bytes, fmt_secs};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let dir = Path::new(concat!(env!("CARGO_MANIFEST_DIR"),
                                "/artifacts/quality_scmoe_micro"));
    anyhow::ensure!(dir.join("manifest.json").exists(), "run `make artifacts` first");
    let engine = Arc::new(Engine::cpu()?);
    let set = engine.open(dir)?;
    let cfg = &set.manifest.config;

    // real expert selections from the AOT infer_step
    println!("running infer_step to collect real gate selections...");
    let params = set.get("init")?.run(&[HostTensor::scalar_i32(0)])?;
    let tokens = HostTensor::i32(
        vec![cfg.batch_size, cfg.seq_len],
        (0..cfg.batch_size * cfg.seq_len).map(|i| (i * 7 % 250) as i32).collect());
    let mut inputs = params;
    inputs.push(tokens);
    let out = set.get("infer_step")?.run(&inputs)?;
    let sel = &out[1];
    let (n_moe, t, k) = (sel.shape[0], sel.shape[1], sel.shape[2]);
    let sel_i = sel.as_i32()?;
    let take = args.usize_or("tokens", 32).min(t);
    let selections: Vec<Vec<Vec<usize>>> = (0..take).map(|tok| {
        (0..n_moe).map(|l| {
            (0..k).map(|kk| sel_i[(l * t + tok) * k + kk] as usize).collect()
        }).collect()
    }).collect();
    println!("collected selections for {take} decode steps x {n_moe} MoE layers (k={k})");

    let mut ocfg = gpt2_moe_medium();
    ocfg.n_moe_layers = n_moe;
    ocfg.n_experts = cfg.n_experts;
    ocfg.k = k;
    println!("\nGPT2-MoE-Medium cost model, single-GPU proxy:");
    println!("{:<18} {:>12} {:>14} {:>14}", "policy", "peak GPU",
             "block latency", "exposed migr");
    for policy in [Policy::GpuOnly, Policy::Blocking, Policy::AsyncDeterminate,
                   Policy::Speculative { accuracy: 0.85 }] {
        let r = simulate_decode(&ocfg, Some(&selections), take, policy, 9);
        println!("{:<18} {:>12} {:>14} {:>14}",
                 r.policy.label(), fmt_bytes(r.peak_gpu_bytes as f64),
                 fmt_secs(r.block_latency), fmt_secs(r.exposed_migration));
    }
    println!("\nScMoE's determinate migration (issued at the preceding layer's");
    println!("gate) hides transfer behind T_Atten + T_SE + T_MLP — no speculation.");
    Ok(())
}
