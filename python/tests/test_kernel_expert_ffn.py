"""L1 correctness: grouped expert-FFN Pallas kernel vs pure-jnp oracle.

hypothesis sweeps shapes/dtypes; every property asserts allclose against
ref.expert_ffn (forward) and jax.grad of the oracle (backward).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import common, expert_ffn, ref

SETTLE = dict(max_examples=12, deadline=None)


def _mk(e, c, d, f, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    sc = 0.5 / np.sqrt(d)
    return (
        jax.random.normal(ks[0], (e, c, d), dtype),
        (jax.random.normal(ks[1], (e, d, f), dtype) * sc),
        (jax.random.normal(ks[2], (e, f), dtype) * 0.1),
        (jax.random.normal(ks[3], (e, f, d), dtype) * sc),
        (jax.random.normal(ks[4], (e, d), dtype) * 0.1),
    )


@settings(**SETTLE)
@given(
    e=st.sampled_from([1, 2, 4, 8]),
    c=st.sampled_from([1, 4, 16, 24]),
    d=st.sampled_from([8, 16, 32]),
    f=st.sampled_from([16, 32, 96]),
)
def test_forward_matches_ref(e, c, d, f):
    args = _mk(e, c, d, f, seed=e * 1000 + c * 10 + d + f)
    y = expert_ffn.expert_ffn(*args)
    yr = ref.expert_ffn(*args)
    np.testing.assert_allclose(y, yr, rtol=2e-5, atol=2e-5)


@settings(**SETTLE)
@given(
    e=st.sampled_from([1, 2, 4]),
    c=st.sampled_from([4, 8, 16]),
    d=st.sampled_from([8, 16]),
    f=st.sampled_from([16, 32]),
)
def test_backward_matches_ref(e, c, d, f):
    args = _mk(e, c, d, f, seed=e + c + d + f)
    f1 = lambda *a: jnp.sum(jnp.sin(expert_ffn.expert_ffn(*a)))
    f2 = lambda *a: jnp.sum(jnp.sin(ref.expert_ffn(*a)))
    g1 = jax.grad(f1, argnums=tuple(range(5)))(*args)
    g2 = jax.grad(f2, argnums=tuple(range(5)))(*args)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5)


@pytest.mark.parametrize("bc", [1, 2, 4, 8, 16])
def test_block_size_invariance(bc):
    """Output must not depend on the token-block tiling."""
    args = _mk(2, 16, 8, 16, seed=7)
    base = expert_ffn.expert_ffn(*args, block_tokens=16)
    tiled = expert_ffn.expert_ffn(*args, block_tokens=bc)
    np.testing.assert_allclose(base, tiled, rtol=1e-6, atol=1e-6)


def test_bf16_forward_close():
    args = _mk(2, 8, 16, 32, dtype=jnp.bfloat16, seed=3)
    y = expert_ffn.expert_ffn(*args).astype(jnp.float32)
    yr = ref.expert_ffn(*[a.astype(jnp.float32) for a in args])
    np.testing.assert_allclose(y, yr, rtol=5e-2, atol=5e-2)


def test_zero_capacity_rows_passthrough():
    """Rows that are all-zero (dropped/padded slots) produce the bias-only
    output — the combine step later zeroes them via the combine mask."""
    e, c, d, f = 2, 4, 8, 16
    args = list(_mk(e, c, d, f, seed=9))
    args[0] = jnp.zeros_like(args[0])
    y = expert_ffn.expert_ffn(*args)
    yr = ref.expert_ffn(*args)
    np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)


def test_jit_and_nonjit_agree():
    args = _mk(2, 8, 16, 32, seed=11)
    y1 = expert_ffn.expert_ffn(*args)
    y2 = jax.jit(lambda *a: expert_ffn.expert_ffn(*a))(*args)
    np.testing.assert_allclose(y1, y2, rtol=1e-6, atol=1e-6)


def test_vmem_block_picker_respects_budget():
    for (c, d, f) in [(64, 128, 512), (512, 512, 2048), (1024, 1024, 4096)]:
        bc = common.ffn_block_tokens(c, d, f)
        assert c % bc == 0
        fp = common.ffn_vmem_footprint(bc, d, f)
        # footprint must fit the usable half of VMEM whenever the weights
        # themselves fit (otherwise the picker falls back to a minimal block)
        if (2 * d * f + f + d) * 4 < common.VMEM_USABLE:
            assert fp <= common.VMEM_BUDGET
