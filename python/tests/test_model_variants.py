"""L2: every architecture traces, has consistent parameter specs, and
produces sane outputs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, train
from compile.config import ARCHS, ModelConfig, preset


@pytest.fixture(scope="module")
def cfgs():
    return {a: preset("micro", arch=a) for a in ARCHS}


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_match_init(cfgs, arch):
    cfg = cfgs[arch]
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    specs = model.param_specs(cfg)
    assert len(params) == len(specs)
    for p, (name, shape) in zip(params, specs):
        assert p.shape == shape, name
    assert model.param_count(cfg) == sum(int(np.prod(s)) for _, s in specs)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes(cfgs, arch):
    cfg = cfgs[arch]
    params = model.init_params(cfg, jax.random.PRNGKey(1))
    tokens = jnp.zeros((cfg.batch_size, cfg.seq_len), jnp.int32)
    out = model.forward(cfg, params, tokens)
    assert out["logits"].shape == (cfg.batch_size, cfg.seq_len, cfg.vocab_size)
    n_moe = 0 if arch == "dense" else cfg.n_moe_blocks
    assert out["stats"].shape[0] == n_moe
    assert out["selections"].shape[0] == n_moe
    assert np.isfinite(float(out["aux"]))


def test_dgmoe_selects_distinct_experts():
    cfg = preset("micro", arch="dgmoe", noisy_gate=False)
    params = model.init_params(cfg, jax.random.PRNGKey(2))
    tokens = jnp.arange(cfg.batch_size * cfg.seq_len, dtype=jnp.int32) % 250
    tokens = tokens.reshape(cfg.batch_size, cfg.seq_len)
    out = model.forward(cfg, params, tokens)
    sel = np.asarray(out["selections"])  # [n_moe, T, 2]
    assert (sel[..., 0] != sel[..., 1]).all(), "DGMoE must activate distinct experts"


def test_dgmoe_share_reuses_parameters():
    cfg_share = preset("micro", arch="dgmoe_share", n_blocks=4 if False else 4)
    cfg_plain = preset("micro", arch="dgmoe")
    # sharing across pairs: with >= 2 pairs the shared variant has fewer params
    cfg_share8 = preset("micro", arch="dgmoe_share", n_blocks=8 if False else 4)
    del cfg_share8
    # with 2 pairs (n_blocks=4... micro has 2 blocks = 1 pair) use 4 blocks:
    c1 = ModelConfig(name="t", arch="dgmoe", d_model=64, n_heads=2, d_ff=256,
                     n_blocks=8, seq_len=32, n_experts=4, batch_size=2)
    c2 = ModelConfig(name="t", arch="dgmoe_share", d_model=64, n_heads=2, d_ff=256,
                     n_blocks=8, seq_len=32, n_experts=4, batch_size=2)
    assert model.param_count(c2) < model.param_count(c1)
    del cfg_share, cfg_plain


def test_scmoe_positions_differ_only_in_shortcut():
    # all three Pos variants share the same parameter count
    counts = {a: model.param_count(preset("micro", arch=a))
              for a in ("scmoe_pos1", "scmoe", "scmoe_pos3")}
    assert len(set(counts.values())) == 1, counts


def test_cls_task_forward():
    cfg = preset("proxy_cls", d_model=64, n_heads=2, d_ff=128, n_blocks=2,
                 seq_len=16, batch_size=4, n_experts=4)
    params = model.init_params(cfg, jax.random.PRNGKey(3))
    tokens = jnp.zeros((4, 16), jnp.int32)
    out = model.forward(cfg, params, tokens)
    assert out["logits"].shape == (4, cfg.n_classes)


def test_se_gate_toggle_changes_params():
    with_gate = model.param_count(preset("micro", arch="scmoe", se_gate=True))
    without = model.param_count(preset("micro", arch="scmoe", se_gate=False))
    assert with_gate > without
