"""L1 correctness: causal attention kernel vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, ref

SETTLE = dict(max_examples=12, deadline=None)


def _mk(h, t, dh, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return tuple(jax.random.normal(ks[i], (h, t, dh)) for i in range(3))


@settings(**SETTLE)
@given(h=st.sampled_from([1, 2, 4]), t=st.sampled_from([1, 4, 16, 64]),
       dh=st.sampled_from([4, 8, 32]), causal=st.booleans())
def test_forward(h, t, dh, causal):
    q, k, v = _mk(h, t, dh, seed=h * 7 + t + dh)
    np.testing.assert_allclose(
        attention.attention(q, k, v, causal=causal),
        ref.attention(q, k, v, causal=causal),
        rtol=2e-5, atol=2e-5,
    )


@settings(**SETTLE)
@given(h=st.sampled_from([1, 2]), t=st.sampled_from([4, 16]), dh=st.sampled_from([4, 8]))
def test_backward(h, t, dh):
    q, k, v = _mk(h, t, dh, seed=h + t + dh)
    f1 = lambda *a: jnp.sum(jnp.sin(attention.attention(*a)))
    f2 = lambda *a: jnp.sum(jnp.sin(ref.attention(*a)))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)


def test_causal_mask_blocks_future():
    """Changing a future token must not change earlier outputs."""
    q, k, v = _mk(1, 8, 4, seed=42)
    y1 = attention.attention(q, k, v, causal=True)
    k2 = k.at[:, -1].set(99.0)
    v2 = v.at[:, -1].set(-99.0)
    y2 = attention.attention(q, k2, v2, causal=True)
    np.testing.assert_allclose(y1[:, :-1], y2[:, :-1], rtol=1e-5, atol=1e-5)


def test_rows_sum_to_convex_combination():
    q, k, v = _mk(2, 16, 8, seed=1)
    v1 = jnp.ones_like(v)
    y = attention.attention(q, k, v1, causal=True)
    np.testing.assert_allclose(y, 1.0, rtol=1e-5, atol=1e-5)
