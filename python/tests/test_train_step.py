"""L2: training-step semantics — Adam update, LR schedule, loss behavior."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, train
from compile.config import preset


@pytest.fixture(scope="module")
def setup():
    cfg = preset("micro", arch="scmoe")
    p = train.init(cfg, jnp.int32(0))
    m = [jnp.zeros_like(t) for t in p]
    v = [jnp.zeros_like(t) for t in p]
    tokens = (jnp.arange(cfg.batch_size * cfg.seq_len, dtype=jnp.int32) % 250
              ).reshape(cfg.batch_size, cfg.seq_len)
    targets = jnp.roll(tokens, -1, axis=1)
    return cfg, p, m, v, tokens, targets


def test_loss_decreases_on_repeated_batch(setup):
    cfg, p, m, v, tokens, targets = setup
    losses = []
    state = (p, m, v)
    for step in range(6):
        p_, m_, v_, loss, aux, acc, stats = train.train_step(
            cfg, *state, jnp.int32(step), tokens, targets, jnp.int32(step))
        losses.append(float(loss))
        state = (p_, m_, v_)
    assert losses[-1] < losses[0], losses


def test_params_change_and_moments_populate(setup):
    cfg, p, m, v, tokens, targets = setup
    p_, m_, v_, *_ = train.train_step(cfg, p, m, v, jnp.int32(0),
                                      tokens, targets, jnp.int32(1))
    changed = sum(int(not np.allclose(a, b)) for a, b in zip(p, p_))
    assert changed > len(p) // 2, f"only {changed}/{len(p)} params changed"
    assert any(float(jnp.abs(x).max()) > 0 for x in m_)
    assert any(float(jnp.abs(x).max()) > 0 for x in v_)


def test_lr_schedule_warmup_then_decay():
    cfg = preset("micro")
    lrs = [float(train.lr_schedule(cfg, jnp.int32(s)))
           for s in [0, 10, 50, 99, 100, 400]]
    # warmup: increasing
    assert lrs[0] < lrs[1] < lrs[2] < lrs[3]
    # decay: decreasing after warmup
    assert lrs[4] >= lrs[5]
    # peak ~ learning_rate
    assert abs(max(lrs) - cfg.learning_rate) / cfg.learning_rate < 0.1


def test_eval_step_deterministic(setup):
    cfg, p, m, v, tokens, targets = setup
    l1, a1 = train.eval_step(cfg, p, tokens, targets)
    l2, a2 = train.eval_step(cfg, p, tokens, targets)
    assert float(l1) == float(l2)
    assert float(a1) == float(a2)


def test_infer_step_selections_valid(setup):
    cfg, p, *_ = setup
    tokens = jnp.zeros((cfg.batch_size, cfg.seq_len), jnp.int32)
    logits, sel = train.infer_step(cfg, p, tokens)
    sel = np.asarray(sel)
    assert sel.min() >= 0 and sel.max() < cfg.n_experts
    assert logits.shape[-1] == cfg.vocab_size
