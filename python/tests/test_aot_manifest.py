"""AOT pipeline: manifests agree with the lowered HLO interfaces and the
HLO text stays within the XLA-0.5.1-parsable subset."""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest(name):
    path = os.path.join(ART, name, "manifest.json")
    if not os.path.exists(path):
        pytest.skip(f"{name} artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f), os.path.join(ART, name)


def test_quality_manifest_interface():
    m, d = _manifest("quality_scmoe_micro")
    assert m["kind"] == "quality"
    n = len(m["param_specs"])
    ts = m["artifacts"]["train_step"]
    assert len(ts["inputs"]) == 3 * n + 4
    assert len(ts["outputs"]) == 3 * n + 4
    # input order contract: params, m.*, v.*, step, tokens, targets, seed
    names = [i["name"] for i in ts["inputs"]]
    assert names[n].startswith("m.")
    assert names[2 * n].startswith("v.")
    assert names[-4:] == ["step", "tokens", "targets", "seed"]
    # init produces exactly the params
    init = m["artifacts"]["init"]
    assert [o["name"] for o in init["outputs"]] == [p[0] for p in m["param_specs"]]
    assert [o["shape"] for o in init["outputs"]] == [p[1] for p in m["param_specs"]]


def test_ops_manifest_capacities():
    m, d = _manifest("ops_tiny")
    assert m["kind"] == "ops"
    t = m["tokens"]
    cfg = m["config"]
    for k, cap in m["capacities"].items():
        expect = int(cfg["capacity_factor"] * t * int(k) / cfg["n_experts"])
        assert cap == max(1, expect)
        assert f"expert_op_c{cap}" in m["artifacts"]
        assert f"moe_fused_op_k{k}" in m["artifacts"]


def test_hlo_text_parsable_subset():
    """The xla_extension 0.5.1 text parser rejects newer HLO instructions;
    guard against regressions (e.g. `topk(...)` from lax.top_k)."""
    m, d = _manifest("quality_scmoe_micro")
    for art in m["artifacts"].values():
        with open(os.path.join(d, art["file"])) as f:
            text = f.read()
        assert " topk(" not in text, f"{art['file']} uses the topk HLO op"
        assert "ragged" not in text, f"{art['file']} uses ragged ops"


def test_all_artifact_files_exist():
    for name in ("quality_scmoe_micro", "quality_top2_micro", "ops_tiny"):
        m, d = _manifest(name)
        for art in m["artifacts"].values():
            assert os.path.exists(os.path.join(d, art["file"])), art["file"]
