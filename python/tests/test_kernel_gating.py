"""L1 correctness: noisy top-k gating kernel vs oracle + gating invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gating, ref

SETTLE = dict(max_examples=16, deadline=None)


def _logits(t, e, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (t, e))


@settings(**SETTLE)
@given(t=st.sampled_from([1, 2, 16, 64]), e=st.sampled_from([4, 8, 16]),
       k=st.sampled_from([1, 2, 3]))
def test_forward_matches_ref(t, e, k):
    logits = _logits(t, e, seed=t * 31 + e + k)
    s, i, w = gating.topk_gating(logits, k)
    sr, ir, wr = ref.topk_gating(logits, k)
    np.testing.assert_allclose(s, sr, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(i, ir)
    np.testing.assert_allclose(w, wr, rtol=1e-5, atol=1e-6)


@settings(**SETTLE)
@given(t=st.sampled_from([4, 32]), e=st.sampled_from([4, 8]), k=st.sampled_from([1, 2]))
def test_weights_sum_to_one(t, e, k):
    s, i, w = gating.topk_gating(_logits(t, e, seed=t + e + k), k)
    np.testing.assert_allclose(jnp.sum(w, -1), 1.0, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(jnp.sum(s, -1), 1.0, rtol=1e-5, atol=1e-5)


@settings(**SETTLE)
@given(t=st.sampled_from([4, 32]), e=st.sampled_from([4, 8]), k=st.sampled_from([2, 3]))
def test_indices_distinct_and_sorted(t, e, k):
    _, i, w = gating.topk_gating(_logits(t, e, seed=t * e + k), k)
    i = np.asarray(i)
    w = np.asarray(w)
    for row_i, row_w in zip(i, w):
        assert len(set(row_i.tolist())) == k
        assert all(row_w[a] >= row_w[a + 1] - 1e-7 for a in range(k - 1))


def test_grad_matches_ref():
    logits = _logits(32, 8, seed=5)
    f1 = lambda lg: jnp.sum(gating.topk_gating(lg, 2)[0] ** 2)
    f2 = lambda lg: jnp.sum(ref.topk_gating(lg, 2)[0] ** 2)
    np.testing.assert_allclose(jax.grad(f1)(logits), jax.grad(f2)(logits),
                               rtol=1e-4, atol=1e-6)


def test_noisy_logits_reduce_to_clean_when_noise_zero():
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    wg = jax.random.normal(jax.random.PRNGKey(1), (8, 4))
    wn = jax.random.normal(jax.random.PRNGKey(2), (8, 4))
    clean = ref.gate_logits(x, wg, None, None)
    noisy0 = ref.gate_logits(x, wg, wn, jnp.zeros((16, 4)))
    np.testing.assert_allclose(clean, noisy0, rtol=1e-6, atol=1e-6)


def test_load_balance_loss_uniform_is_one():
    """Perfectly uniform routing gives aux loss == 1 (E * E * (1/E)^2)."""
    t, e = 64, 8
    logits = jnp.zeros((t, e))
    # break ties deterministically but evenly: one-hot rotate
    logits = logits.at[jnp.arange(t), jnp.arange(t) % e].set(1.0)
    s, _, _ = ref.topk_gating(logits, 1)
    aux = ref.load_balance_loss(logits, s, 1)
    assert 0.9 < float(aux) < 1.3
