"""L1 correctness: fused LayerNorm kernel vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import layernorm, ref

SETTLE = dict(max_examples=16, deadline=None)


def _mk(t, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (
        jax.random.normal(ks[0], (t, d)) * 3.0 + 1.0,
        jax.random.normal(ks[1], (d,)) * 0.2 + 1.0,
        jax.random.normal(ks[2], (d,)) * 0.2,
    )


@settings(**SETTLE)
@given(t=st.sampled_from([1, 2, 16, 64, 96]), d=st.sampled_from([4, 8, 32, 128]))
def test_forward(t, d):
    x, g, b = _mk(t, d, seed=t * 131 + d)
    np.testing.assert_allclose(
        layernorm.layernorm(x, g, b), ref.layernorm(x, g, b), rtol=1e-5, atol=1e-5
    )


@settings(**SETTLE)
@given(t=st.sampled_from([2, 16, 32]), d=st.sampled_from([8, 32]))
def test_backward(t, d):
    x, g, b = _mk(t, d, seed=t + d)
    f1 = lambda *a: jnp.sum(jnp.tanh(layernorm.layernorm(*a)))
    f2 = lambda *a: jnp.sum(jnp.tanh(ref.layernorm(*a)))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(x, g, b)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(x, g, b)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(a, b_, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("bt", [1, 2, 4, 8])
def test_block_invariance(bt):
    x, g, b = _mk(8, 16, seed=5)
    np.testing.assert_allclose(
        layernorm.layernorm(x, g, b, block_tokens=bt),
        layernorm.layernorm(x, g, b, block_tokens=8),
        rtol=1e-6, atol=1e-6,
    )


def test_output_is_normalized():
    x, _, _ = _mk(32, 64, seed=2)
    y = layernorm.layernorm(x, jnp.ones(64), jnp.zeros(64))
    np.testing.assert_allclose(jnp.mean(y, -1), 0.0, atol=1e-5)
    np.testing.assert_allclose(jnp.var(y, -1), 1.0, rtol=1e-3, atol=1e-3)
