"""Reference data-plane invariants (mirrored by rust/src/moe/dispatch.rs).

These tests pin the exact dispatch/combine semantics the Rust coordinator
must reproduce: FCFS capacity assignment, overflow dropping, weighted
combine, order restoration.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

SETTLE = dict(max_examples=16, deadline=None)


def _route(t, e, k, seed):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (t, e))
    _, idx, w = ref.topk_gating(logits, k)
    return idx, w


@settings(**SETTLE)
@given(t=st.sampled_from([4, 16, 64]), e=st.sampled_from([4, 8]),
       k=st.sampled_from([1, 2]))
def test_dispatch_mask_is_binary_and_capacity_bounded(t, e, k):
    idx, w = _route(t, e, k, seed=t + e + k)
    cap = max(1, (t * k) // e)
    disp, comb = ref.dispatch_combine_masks(idx, w, e, cap)
    d = np.asarray(disp)
    assert set(np.unique(d)).issubset({0.0, 1.0})
    # each (expert, slot) holds at most one token
    assert (d.sum(axis=0) <= 1.0 + 1e-6).all()
    # each token-expert route uses at most one slot
    assert (d.sum(axis=2) <= k + 1e-6).all()


@settings(**SETTLE)
@given(t=st.sampled_from([4, 16]), e=st.sampled_from([4, 8]), k=st.sampled_from([1, 2]))
def test_infinite_capacity_is_lossless(t, e, k):
    idx, w = _route(t, e, k, seed=t * e * k)
    disp, comb = ref.dispatch_combine_masks(idx, w, e, t * k)
    # every (token, k) route lands somewhere
    assert float(jnp.sum(disp)) == pytest.approx(t * k)
    # combining ones recovers the gate weight sums (=1 per token)
    ones = jnp.ones((e, t * k, 1))
    y = jnp.einsum("ecd,tec->td", ones, comb)
    np.testing.assert_allclose(y[:, 0], np.asarray(w).sum(-1), rtol=1e-5, atol=1e-5)


def test_overflow_drops_latest_tokens_first():
    """With capacity 1 and all tokens routed to expert 0, only token 0 stays."""
    idx = jnp.zeros((4, 1), dtype=jnp.int32)
    w = jnp.ones((4, 1))
    disp, comb = ref.dispatch_combine_masks(idx, w, 2, 1)
    d = np.asarray(disp)
    assert d[0, 0, 0] == 1.0
    assert d[1:, :, :].sum() == 0.0


@settings(**SETTLE)
@given(t=st.sampled_from([8, 32]), e=st.sampled_from([4, 8]))
def test_moe_layer_matches_manual_composition(t, e):
    d_model, d_ff, k = 16, 32, 2
    keys = jax.random.split(jax.random.PRNGKey(t + e), 6)
    x = jax.random.normal(keys[0], (t, d_model))
    wg = jax.random.normal(keys[1], (d_model, e)) * 0.3
    w1 = jax.random.normal(keys[2], (e, d_model, d_ff)) * 0.2
    b1 = jnp.zeros((e, d_ff))
    w2 = jax.random.normal(keys[3], (e, d_ff, d_model)) * 0.2
    b2 = jnp.zeros((e, d_model))
    cap = t  # ample
    y, aux, scores = ref.moe_layer(x, wg, k, cap, w1, b1, w2, b2)
    # manual: for each token sum_k w_k * FFN_{idx_k}(x_t)
    logits = x @ wg
    _, idx, w = ref.topk_gating(logits, k)
    y_manual = np.zeros((t, d_model), dtype=np.float32)
    for ti in range(t):
        for kk in range(k):
            eidx = int(idx[ti, kk])
            ye = ref.ffn(x[ti:ti + 1], w1[eidx], b1[eidx], w2[eidx], b2[eidx])
            y_manual[ti] += float(w[ti, kk]) * np.asarray(ye)[0]
    np.testing.assert_allclose(y, y_manual, rtol=2e-4, atol=2e-4)
