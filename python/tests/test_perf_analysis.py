"""Structural perf-analysis invariants (the §Perf tooling itself)."""

from compile.kernels import common


def test_vmem_budget_respected_at_paper_shapes():
    # SwinV2-MoE-S stage-3 shapes and the GPT ladder must all fit VMEM with
    # double-buffering headroom after block-size selection.
    for (c, d, f) in [(1024, 96, 384), (512, 128, 512), (256, 256, 1024),
                      (2048, 512, 2048)]:
        bc = common.ffn_block_tokens(c, d, f)
        fp = common.ffn_vmem_footprint(bc, d, f)
        assert c % bc == 0
        assert fp <= common.VMEM_BUDGET, (c, d, f, bc, fp)


def test_mxu_estimate_monotone_in_alignment():
    # 128-aligned tiles achieve full occupancy; misaligned ones less.
    assert common.mxu_utilization_estimate(128, 128, 128) == 1.0
    assert common.mxu_utilization_estimate(96, 128, 128) < 1.0
    assert common.mxu_utilization_estimate(96, 128, 128) == 96 / 128


def test_flops_counts():
    assert common.flops_expert_ffn(1, 1, 1, 1) == 4
    assert common.flops_expert_ffn(8, 128, 96, 384) == 2 * 8 * 128 * 2 * 96 * 384
