"""Structural performance analysis for L1/L2 (EXPERIMENTS.md §Perf).

L1 (Pallas): interpret=True gives CPU-numpy timings only, so kernel quality
is assessed structurally — VMEM footprint of each BlockSpec schedule and
MXU-occupancy estimates for the matmul tiles (DESIGN.md §9).

L2 (JAX): XLA cost analysis of the lowered modules — FLOPs, bytes accessed,
and the arithmetic-intensity ratio the CPU/TPU roofline cares about.

Usage:  python -m compile.perf [--preset tiny] [--arch scmoe]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from . import model, train
from .config import preset
from .kernels import common


def l1_report(cfg) -> None:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    cap = cfg.expert_capacity(cfg.tokens_per_batch())
    bc = common.ffn_block_tokens(cap, d, f)
    fp = common.ffn_vmem_footprint(bc, d, f)
    print(f"== L1 expert_ffn kernel ({cfg.name}: E={e} C={cap} D={d} F={f}) ==")
    print(f"  token-block BC        : {bc}")
    print(f"  VMEM/grid-step        : {fp / 1024:.0f} KiB "
          f"(budget {common.VMEM_BUDGET // 1024} KiB, "
          f"{100 * fp / common.VMEM_BUDGET:.0f}% occupied)")
    u1 = common.mxu_utilization_estimate(bc, d, f)
    u2 = common.mxu_utilization_estimate(bc, f, d)
    print(f"  MXU occupancy (x@w1)  : {u1:.2f}  (tiles {bc}x{d}x{f} pad->128)")
    print(f"  MXU occupancy (h@w2)  : {u2:.2f}")
    flops = common.flops_expert_ffn(e, cap, d, f)
    hbm = (e * (2 * d * f + f + d) + 2 * e * cap * d) * 4
    print(f"  FLOPs/layer           : {flops / 1e6:.1f} MFLOP, "
          f"HBM traffic {hbm / 1e6:.2f} MB, intensity {flops / hbm:.1f} FLOP/B")
    # paper-efficiency framing: ratio to a dense top-2 FFN of equal activated
    # params (ScMoE activates 1 routed + 1 shared = same as top-2)
    print(f"  double-buffer headroom: {'yes' if fp < common.VMEM_USABLE else 'NO'}")


def l2_report(cfg) -> None:
    specs = model.param_specs(cfg)
    pspecs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs]
    tok = jax.ShapeDtypeStruct((cfg.batch_size, cfg.seq_len), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    n = len(pspecs)

    def tstep(*flat):
        p, m, v = list(flat[:n]), list(flat[n:2 * n]), list(flat[2 * n:3 * n])
        step, tokens, targets, seed = flat[3 * n:]
        out = train.train_step(cfg, p, m, v, step, tokens, targets, seed)
        return tuple(out[0]) + (out[3],)

    lowered = jax.jit(tstep, keep_unused=True).lower(
        *(pspecs * 3 + [scalar, tok, tok if cfg.task == "lm" else
                        jax.ShapeDtypeStruct((cfg.batch_size,), jnp.int32), scalar]))
    compiled = lowered.compile()
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        flops = ca.get("flops", float("nan"))
        bytes_ = ca.get("bytes accessed", float("nan"))
        print(f"== L2 train_step ({cfg.arch}/{cfg.name}) ==")
        print(f"  params               : {model.param_count(cfg) / 1e6:.2f} M")
        print(f"  FLOPs/step           : {flops / 1e9:.2f} GFLOP")
        print(f"  bytes accessed/step  : {bytes_ / 1e9:.2f} GB")
        print(f"  arithmetic intensity : {flops / bytes_:.2f} FLOP/B")
        toks = cfg.tokens_per_batch()
        print(f"  FLOPs/token          : {flops / toks / 1e6:.2f} MFLOP "
              f"(6*P = {6 * model.param_count(cfg) / 1e6:.1f} expected for dense)")
    except Exception as e:  # cost analysis availability varies by version
        print(f"  cost analysis unavailable: {e}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny")
    ap.add_argument("--arch", default="scmoe")
    ap.add_argument("--skip-l2", action="store_true")
    args = ap.parse_args()
    cfg = preset(args.preset, arch=args.arch)
    l1_report(cfg)
    if not args.skip_l2:
        l2_report(cfg)


if __name__ == "__main__":
    main()
