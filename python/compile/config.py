"""Shared model/compile configuration for the ScMoE reproduction.

This module is the single source of truth for model shapes on the Python
(build-time) side. The AOT pipeline (`aot.py`) serializes the active config
into `manifest.json`, which the Rust coordinator reads; Rust never needs to
know how the model was traced, only the flattened tensor interface.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Tuple

# Architectures under study.  These mirror the paper's Table 2/3/6/7 rows
# plus the appendix variants.
ARCHS = (
    "dense",        # plain transformer (MLP in every block)
    "top1",         # standard top-1 MoE      (Table 2)
    "top2",         # standard top-2 MoE      (baseline everywhere)
    "top3",         # standard top-3 MoE      (Table 4)
    "shared",       # shared-expert MoE: SE + top-1   (Fig 2b)
    "scmoe_pos1",   # ScMoE, shortcut from preceding block *output*
    "scmoe",        # ScMoE Pos-2 (default): shortcut from preceding
                    # block's post-attention intermediate  (Fig 4b)
    "scmoe_pos3",   # ScMoE, shortcut from preceding block *input*
    "scmoe2",       # ScMoE-2: SE + top-2 on the shortcut  (Table 4)
    "dgmoe",        # DoubleGating MoE (Appendix A.2)
    "dgmoe_share",  # DGMoE with one MoE shared across two pairs (A.5)
)

# Architectures whose MoE consumes the *preceding layer's* representation,
# i.e. whose All-to-All can be decoupled and overlapped (the paper's core).
SHORTCUT_ARCHS = ("scmoe_pos1", "scmoe", "scmoe_pos3", "scmoe2", "dgmoe", "dgmoe_share")


@dataclass
class ModelConfig:
    """One experiment's model hyperparameters (paper Appendix Tables 8/9)."""

    name: str = "tiny"
    arch: str = "scmoe"
    task: str = "lm"            # "lm" (GPT-MoE) | "cls" (SwinV2-MoE proxy)

    vocab_size: int = 259       # byte-level + BOS/EOS/PAD
    n_classes: int = 16         # cls task head size
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 512
    n_blocks: int = 4           # must be even: Block-MLP / Block-MoE pairs
    n_experts: int = 8
    seq_len: int = 128
    capacity_factor: float = 2.0
    moe_loss_coef: float = 0.01
    se_gate: bool = True        # shared-expert gate (Appendix A.3)
    noisy_gate: bool = True     # noisy top-k gating (Eq. 4/5) at train time

    # training
    batch_size: int = 8
    learning_rate: float = 1e-3
    warmup_steps: int = 100
    adam_b1: float = 0.9
    adam_b2: float = 0.98
    adam_eps: float = 1e-9
    weight_decay: float = 0.0

    dtype: str = "f32"

    def __post_init__(self) -> None:
        if self.arch not in ARCHS:
            raise ValueError(f"unknown arch {self.arch!r}; expected one of {ARCHS}")
        if self.n_blocks % 2 != 0:
            raise ValueError("n_blocks must be even (Block-MLP/Block-MoE pairs)")
        if self.d_model % self.n_heads != 0:
            raise ValueError("d_model must be divisible by n_heads")
        if self.task not in ("lm", "cls"):
            raise ValueError(f"unknown task {self.task!r}")

    # ---- derived quantities -------------------------------------------------

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def n_moe_blocks(self) -> int:
        return self.n_blocks // 2

    @property
    def top_k(self) -> int:
        """Number of gate-selected experts routed through All-to-All."""
        return {
            "dense": 0,
            "top1": 1,
            "top2": 2,
            "top3": 3,
            "shared": 1,
            "scmoe_pos1": 1,
            "scmoe": 1,
            "scmoe_pos3": 1,
            "scmoe2": 2,
            "dgmoe": 2,
            "dgmoe_share": 2,
        }[self.arch]

    @property
    def has_shared_expert(self) -> bool:
        return self.arch in ("shared", "scmoe_pos1", "scmoe", "scmoe_pos3", "scmoe2")

    @property
    def uses_shortcut(self) -> bool:
        return self.arch in SHORTCUT_ARCHS

    def expert_capacity(self, tokens: int) -> int:
        """GShard-style per-expert capacity for a batch of `tokens` tokens."""
        k = max(self.top_k, 1)
        cap = int(self.capacity_factor * tokens * k / self.n_experts)
        return max(cap, 1)

    def tokens_per_batch(self) -> int:
        return self.batch_size * self.seq_len

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "ModelConfig":
        return ModelConfig(**d)


# ---- presets ---------------------------------------------------------------
#
# "tiny"/"small"/"medium" are the quality-experiment ladder (the paper's
# GPT2-MoE-Small/Medium scaled to a single-CPU testbed, Appendix Table 8);
# "e2e" is the end-to-end driver config (~100M-class parameter budget,
# see EXPERIMENTS.md for the measured count); "proxy_cls" stands in for
# SwinV2-MoE-S on the classification task.

def preset(name: str, **overrides: Any) -> ModelConfig:
    base: Dict[str, Dict[str, Any]] = {
        "micro": dict(d_model=64, n_heads=2, d_ff=256, n_blocks=2, seq_len=32,
                      n_experts=4, batch_size=4),
        "tiny": dict(d_model=128, n_heads=4, d_ff=512, n_blocks=4, seq_len=128,
                     n_experts=8, batch_size=8),
        "small": dict(d_model=256, n_heads=8, d_ff=1024, n_blocks=8, seq_len=128,
                      n_experts=8, batch_size=8),
        "medium": dict(d_model=384, n_heads=8, d_ff=1536, n_blocks=12, seq_len=128,
                       n_experts=8, batch_size=4),
        # ~100M-class config for the end-to-end example (params dominated by
        # 8-expert MoE FFNs: n_moe_blocks * E * 2*d*ff).
        "e2e": dict(d_model=512, n_heads=8, d_ff=2048, n_blocks=8, seq_len=256,
                    n_experts=8, batch_size=4),
        "proxy_cls": dict(task="cls", d_model=128, n_heads=4, d_ff=512,
                          n_blocks=4, seq_len=64, n_experts=8, batch_size=16,
                          capacity_factor=1.25),
        "proxy_cls_b": dict(task="cls", d_model=192, n_heads=6, d_ff=768,
                            n_blocks=4, seq_len=64, n_experts=8, batch_size=16,
                            capacity_factor=1.25),
    }
    if name not in base:
        raise ValueError(f"unknown preset {name!r}; have {sorted(base)}")
    kw = dict(base[name])
    kw.update(overrides)
    return ModelConfig(name=name, **kw)


def param_count(cfg: ModelConfig) -> int:
    """Exact parameter count for the manifest (mirrors model.init_params)."""
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    n = 0
    n += cfg.vocab_size * d                      # tok embed
    n += cfg.seq_len * d                         # pos embed
    for b in range(cfg.n_blocks):
        n += 2 * 2 * d                           # 2 × LN (gamma, beta)
        n += 4 * d * d + 4 * d                   # attn qkv+o with bias
        is_moe = b % 2 == 1 and cfg.arch != "dense"
        if not is_moe:
            n += d * f + f + f * d + d           # MLP
        else:
            shared_pairs = cfg.arch == "dgmoe_share"
            # dgmoe_share: MoE params counted once per two pairs (handled
            # by the model by reusing the first pair's params).
            pair_idx = (b // 2)
            counted = not shared_pairs or pair_idx % 2 == 0
            if counted:
                n += d * e + (d * e if cfg.noisy_gate else 0)   # gate (+noise)
                n += e * (d * f + f + f * d + d)                 # experts
            if cfg.has_shared_expert:
                n += d * f + f + f * d + d                       # shared expert
                if cfg.se_gate:
                    n += d                                       # SE-gate vector
            if cfg.arch == "dgmoe" or cfg.arch == "dgmoe_share":
                pass  # dual gating reuses the same gate matrix
    n += 2 * d                                   # final LN
    if cfg.task == "lm":
        n += d * cfg.vocab_size                  # lm head (untied)
    else:
        n += d * cfg.n_classes + cfg.n_classes   # cls head
    return n


if __name__ == "__main__":  # quick inspection helper
    for p in ("micro", "tiny", "small", "medium", "e2e", "proxy_cls"):
        c = preset(p)
        print(f"{p:10s} params≈{param_count(c)/1e6:8.2f}M  "
              f"tokens/batch={c.tokens_per_batch()}")
