"""AOT pipeline: lower every jitted step/operator to HLO text + manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the `xla` crate binds) rejects; the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Usage:
  python -m compile.aot --profile quality --arch scmoe --preset tiny --out DIR
  python -m compile.aot --profile ops --preset tiny --tokens 1024 --out DIR
  python -m compile.aot --suite default --out-root ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import json
import os
from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, ops, train
from .config import ModelConfig, preset

F32 = "f32"
I32 = "i32"
U32 = "u32"

_DTYPES = {F32: jnp.float32, I32: jnp.int32, U32: jnp.uint32}


def spec(shape: Sequence[int], dtype: str = F32) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), _DTYPES[dtype])


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _iospec(specs, names) -> List[Dict[str, Any]]:
    out = []
    for s, n in zip(specs, names):
        dt = {jnp.float32: F32, jnp.int32: I32, jnp.uint32: U32}[
            jnp.dtype(s.dtype).type if hasattr(s, "dtype") else s]
        out.append({"name": n, "shape": list(s.shape), "dtype": dt})
    return out


class ArtifactWriter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        os.makedirs(out_dir, exist_ok=True)
        self.entries: Dict[str, Any] = {}

    def lower(self, name: str, fn, in_specs: List[jax.ShapeDtypeStruct],
              in_names: List[str], out_names: List[str]):
        lowered = jax.jit(fn, keep_unused=True).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *in_specs)
        flat_outs, _ = jax.tree_util.tree_flatten(outs)
        self.entries[name] = {
            "file": fname,
            "inputs": _iospec(in_specs, in_names),
            "outputs": _iospec(flat_outs, out_names or
                               [f"out{i}" for i in range(len(flat_outs))]),
        }
        print(f"  lowered {name}: {len(text)} chars, "
              f"{len(in_specs)} in / {len(flat_outs)} out")

    def finish(self, meta: Dict[str, Any]):
        manifest = dict(meta)
        manifest["artifacts"] = self.entries
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"  wrote {path}")


# ---------------------------------------------------------------------------
# quality profile: init / train_step / eval_step / infer_step per (arch, size)
# ---------------------------------------------------------------------------

def build_quality(cfg: ModelConfig, out_dir: str):
    w = ArtifactWriter(out_dir)
    specs = model.param_specs(cfg)
    pnames = [n for n, _ in specs]
    pspecs = [spec(s) for _, s in specs]
    npar = len(pspecs)
    bsz, s = cfg.batch_size, cfg.seq_len
    tok = spec((bsz, s), I32)
    tgt = spec((bsz, s) if cfg.task == "lm" else (bsz,), I32)
    scalar_i = spec((), I32)

    w.lower("init", lambda seed: tuple(train.init(cfg, seed)),
            [scalar_i], ["seed"], pnames)

    def tstep(*flat):
        p = list(flat[:npar])
        m = list(flat[npar:2 * npar])
        v = list(flat[2 * npar:3 * npar])
        step, tokens, targets, seed = flat[3 * npar:]
        np_, nm, nv, loss, aux, acc, stats = train.train_step(
            cfg, p, m, v, step, tokens, targets, seed)
        return tuple(np_) + tuple(nm) + tuple(nv) + (loss, aux, acc, stats)

    in_specs = pspecs * 3 + [scalar_i, tok, tgt, scalar_i]
    in_names = (pnames + [f"m.{n}" for n in pnames] + [f"v.{n}" for n in pnames]
                + ["step", "tokens", "targets", "seed"])
    out_names = (pnames + [f"m.{n}" for n in pnames] + [f"v.{n}" for n in pnames]
                 + ["loss", "aux", "acc", "stats"])
    w.lower("train_step", tstep, in_specs, in_names, out_names)

    # fused multi-step artifact (scan over MULTI steps): the training-driver
    # hot-path optimization measured in EXPERIMENTS.md §Perf.
    multi = 4
    tok_n = spec((multi, bsz, s), I32)
    tgt_n = spec((multi,) + ((bsz, s) if cfg.task == "lm" else (bsz,)), I32)

    def tstep_n(*flat):
        p = list(flat[:npar])
        m = list(flat[npar:2 * npar])
        v = list(flat[2 * npar:3 * npar])
        step, tokens_n, targets_n, seed = flat[3 * npar:]
        p2, m2, v2, losses, accs = train.train_step_n(
            cfg, p, m, v, step, tokens_n, targets_n, seed, multi)
        return tuple(p2) + tuple(m2) + tuple(v2) + (losses, accs)

    w.lower(f"train_step_{multi}", tstep_n,
            pspecs * 3 + [scalar_i, tok_n, tgt_n, scalar_i],
            in_names[:3 * npar] + ["step", "tokens_n", "targets_n", "seed"],
            pnames + [f"m.{n}" for n in pnames] + [f"v.{n}" for n in pnames]
            + ["losses", "accs"])

    w.lower("eval_step",
            lambda *flat: train.eval_step(cfg, list(flat[:npar]), flat[npar], flat[npar + 1]),
            pspecs + [tok, tgt], pnames + ["tokens", "targets"],
            ["loss", "acc"])

    w.lower("infer_step",
            lambda *flat: train.infer_step(cfg, list(flat[:npar]), flat[npar]),
            pspecs + [tok], pnames + ["tokens"],
            ["logits", "selections"])

    w.finish({
        "version": 1,
        "kind": "quality",
        "config": cfg.to_json(),
        "param_specs": [[n, list(s)] for n, s in specs],
        "param_count": model.param_count(cfg),
        "stats_fields": list(model.STATS_FIELDS),
        "n_moe_blocks": cfg.n_moe_blocks if cfg.arch != "dense" else 0,
        "capacity": cfg.expert_capacity(cfg.tokens_per_batch()),
    })


# ---------------------------------------------------------------------------
# ops profile: per-operator artifacts at one shape point (for the
# coordinator's distributed execution + DES calibration)
# ---------------------------------------------------------------------------

def build_ops(cfg: ModelConfig, tokens: int, out_dir: str):
    w = ArtifactWriter(out_dir)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    t = tokens
    x = spec((t, d))
    vec = lambda *sh: spec(sh)

    w.lower("ops_init", lambda seed: ops.ops_init(cfg, seed), [spec((), I32)],
            ["seed"],
            ["ln_g", "ln_b", "wqkv", "bqkv", "wo", "bo",
             "mlp_w1", "mlp_b1", "mlp_w2", "mlp_b2",
             "wg", "moe_w1", "moe_b1", "moe_w2", "moe_b2", "segate_w"])

    w.lower("attn_op",
            lambda *a: ops.attn_op(cfg, *a),
            [x, vec(d), vec(d), vec(d, 3 * d), vec(3 * d), vec(d, d), vec(d)],
            ["x", "ln_g", "ln_b", "wqkv", "bqkv", "wo", "bo"], ["y"])

    w.lower("mlp_op",
            lambda *a: ops.mlp_op(cfg, *a),
            [x, vec(d), vec(d), vec(d, f), vec(f), vec(f, d), vec(d)],
            ["x", "ln_g", "ln_b", "w1", "b1", "w2", "b2"], ["y"])

    w.lower("se_op",
            lambda *a: ops.se_op(cfg, *a),
            [x, vec(d), vec(d), vec(d, f), vec(f), vec(f, d), vec(d), vec(d)],
            ["x", "ln_g", "ln_b", "w1", "b1", "w2", "b2", "segate_w"], ["y"])

    caps = {}
    for k in (1, 2, 3):
        cap = max(1, int(cfg.capacity_factor * t * k / e))
        caps[str(k)] = cap
        w.lower(f"gate_op_k{k}",
                lambda x_, g_, b_, wg_, k=k: ops.gate_op(cfg, x_, g_, b_, wg_, k),
                [x, vec(d), vec(d), vec(d, e)],
                ["x", "ln_g", "ln_b", "wg"], ["h", "indices", "weights"])
        w.lower(f"expert_op_c{cap}",
                lambda xe, w1, b1, w2, b2: ops.expert_op(cfg, xe, w1, b1, w2, b2),
                [spec((cap, d)), vec(d, f), vec(f), vec(f, d), vec(d)],
                ["xe", "w1", "b1", "w2", "b2"], ["ye"])
        w.lower(f"experts_op_c{cap}",
                lambda xe, w1, b1, w2, b2: ops.experts_op(cfg, xe, w1, b1, w2, b2),
                [spec((e, cap, d)), spec((e, d, f)), spec((e, f)),
                 spec((e, f, d)), spec((e, d))],
                ["xe", "w1", "b1", "w2", "b2"], ["ye"])
        w.lower(f"moe_fused_op_k{k}",
                lambda x_, g_, b_, wg_, w1, b1, w2, b2, k=k, cap=cap:
                    ops.moe_fused_op(cfg, x_, g_, b_, wg_, w1, b1, w2, b2, k, cap),
                [x, vec(d), vec(d), vec(d, e), spec((e, d, f)), spec((e, f)),
                 spec((e, f, d)), spec((e, d))],
                ["x", "ln_g", "ln_b", "wg", "w1", "b1", "w2", "b2"], ["y"])

    w.finish({
        "version": 1,
        "kind": "ops",
        "config": cfg.to_json(),
        "tokens": t,
        "capacities": caps,
        "token_bytes": d * 4,
        "expert_param_bytes": (d * f + f + f * d + d) * 4,
    })


# ---------------------------------------------------------------------------
# suites
# ---------------------------------------------------------------------------

def suite_default(out_root: str):
    """The artifact set `make artifacts` builds: enough for cargo test +
    the quickstart/distributed examples + calibration."""
    print("[aot] ops profile (tiny shapes)")
    build_ops(preset("tiny"), tokens=512, out_dir=os.path.join(out_root, "ops_tiny"))
    for arch in ("top2", "scmoe"):
        print(f"[aot] quality micro/{arch}")
        cfg = preset("micro", arch=arch)
        build_quality(cfg, os.path.join(out_root, f"quality_{arch}_micro"))


def parse_arch(name: str):
    """`<arch>[_nosegate]` -> (arch, overrides). The _nosegate suffix builds
    the Appendix A.3 ablation (shared-expert gate disabled)."""
    if name.endswith("_nosegate"):
        return name[: -len("_nosegate")], {"se_gate": False}
    return name, {}


def suite_quality(out_root: str, preset_name: str, archs: List[str]):
    for name in archs:
        arch, over = parse_arch(name)
        print(f"[aot] quality {preset_name}/{name}")
        cfg = preset(preset_name, arch=arch, **over)
        build_quality(cfg, os.path.join(out_root, f"quality_{name}_{preset_name}"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", choices=["quality", "ops"], default=None)
    ap.add_argument("--suite", choices=["default"], default=None)
    ap.add_argument("--arch", default="scmoe")
    ap.add_argument("--archs", default=None, help="comma list for quality suites")
    ap.add_argument("--preset", default="tiny")
    ap.add_argument("--tokens", type=int, default=512)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--batch-size", type=int, default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--out-root", default="../artifacts")
    args = ap.parse_args()

    if args.suite:
        suite_default(args.out_root)
        return
    over = {}
    if args.seq_len:
        over["seq_len"] = args.seq_len
    if args.batch_size:
        over["batch_size"] = args.batch_size
    if args.profile == "quality":
        if args.archs:
            suite_quality(args.out_root, args.preset, args.archs.split(","))
        else:
            cfg = preset(args.preset, arch=args.arch, **over)
            out = args.out or os.path.join(args.out_root,
                                           f"quality_{args.arch}_{args.preset}")
            build_quality(cfg, out)
    elif args.profile == "ops":
        cfg = preset(args.preset, **over)
        out = args.out or os.path.join(args.out_root, f"ops_{args.preset}")
        build_ops(cfg, args.tokens, out)
    else:
        ap.error("need --profile or --suite")


if __name__ == "__main__":
    main()
