"""Shared helpers for the Pallas kernels (block sizing, VMEM accounting)."""

from __future__ import annotations

import math
from typing import Tuple

# Pallas on this image must run in interpret mode: real TPU lowering emits a
# Mosaic custom-call that the CPU PJRT plugin cannot execute. All kernels
# take `interpret=` and default to True.
INTERPRET_DEFAULT = True

# TPU-v4-class VMEM budget used for the §Perf structural analysis
# (bytes; ~16 MiB per core, half reserved for double buffering).
VMEM_BUDGET = 16 * 1024 * 1024
VMEM_USABLE = VMEM_BUDGET // 2


def largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap (>=1). Used to pick block sizes
    that tile the axis exactly — Pallas block shapes must divide the axis in
    the configurations we emit (shapes are static at AOT time)."""
    cap = max(1, min(n, cap))
    for b in range(cap, 0, -1):
        if n % b == 0:
            return b
    return 1


def ffn_block_tokens(c: int, d: int, f: int, dtype_bytes: int = 4,
                     budget: int = VMEM_USABLE) -> int:
    """Pick the token-block size BC for the expert-FFN kernel so that
    x-block + w1 + b1 + w2 + b2 + h-block + out-block fit the VMEM budget.

    Weights for one expert are resident per grid step:
      w1: d*f, w2: f*d, b1: f, b2: d
    Per-token activations: x: d, h: f, out: d.
    """
    weight_bytes = (2 * d * f + f + d) * dtype_bytes
    per_token = (2 * d + f) * dtype_bytes
    avail = budget - weight_bytes
    if avail <= 0:
        # weights alone exceed budget: fall back to the smallest block and
        # report pressure via vmem_footprint (the analysis will flag it).
        return largest_divisor_leq(c, 8)
    cap = max(1, avail // per_token)
    # round to a multiple of 8 below the cap when possible (lane alignment)
    cap = max(8, (cap // 8) * 8) if cap >= 8 else cap
    return largest_divisor_leq(c, min(cap, 512))


def ffn_vmem_footprint(bc: int, d: int, f: int, dtype_bytes: int = 4) -> int:
    """Bytes resident in VMEM for one expert-FFN grid step."""
    return ((2 * d * f + f + d) + bc * (2 * d + f)) * dtype_bytes


def mxu_utilization_estimate(m: int, k: int, n: int, tile: int = 128) -> float:
    """Fraction of MXU lanes doing useful work for an m x k x n matmul when
    dimensions are padded up to `tile` (systolic-array occupancy estimate)."""
    pad = lambda v: math.ceil(v / tile) * tile
    useful = m * k * n
    padded = pad(m) * pad(k) * pad(n)
    return useful / padded


def flops_expert_ffn(e: int, c: int, d: int, f: int) -> int:
    """MAC-based FLOP count (2 per MAC) for the grouped expert FFN."""
    return 2 * e * c * (d * f + f * d)
