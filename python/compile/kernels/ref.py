"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function here is the mathematically-obvious implementation of the
corresponding kernel in this package. pytest compares kernel outputs against
these under hypothesis-driven shape/dtype sweeps; they are also used by the
L2 model as the autodiff reference when deriving custom_vjp rules.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# LayerNorm
# ---------------------------------------------------------------------------

def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    """LayerNorm over the last axis. x: [..., D]; gamma/beta: [D]."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    xhat = (x - mu) * jax.lax.rsqrt(var + eps)
    return xhat * gamma + beta


# ---------------------------------------------------------------------------
# Dense MLP / shared expert (GELU FFN)
# ---------------------------------------------------------------------------

def gelu(x: jax.Array) -> jax.Array:
    """tanh-approximation GELU (matches the kernel's polynomial)."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def ffn(x: jax.Array, w1: jax.Array, b1: jax.Array,
        w2: jax.Array, b2: jax.Array) -> jax.Array:
    """Two-layer GELU FFN. x: [T, D]; w1: [D, F]; w2: [F, D]."""
    h = gelu(x @ w1 + b1)
    return h @ w2 + b2


# ---------------------------------------------------------------------------
# Grouped expert FFN (the MoE compute hot-spot)
# ---------------------------------------------------------------------------

def expert_ffn(x: jax.Array, w1: jax.Array, b1: jax.Array,
               w2: jax.Array, b2: jax.Array) -> jax.Array:
    """Per-expert FFN over capacity-grouped tokens.

    x: [E, C, D] tokens already dispatched to experts (C = capacity).
    w1: [E, D, F], b1: [E, F], w2: [E, F, D], b2: [E, D].
    Returns [E, C, D].
    """
    h = gelu(jnp.einsum("ecd,edf->ecf", x, w1) + b1[:, None, :])
    return jnp.einsum("ecf,efd->ecd", h, w2) + b2[:, None, :]


# ---------------------------------------------------------------------------
# Noisy top-k gating (Shazeer et al. 2017, Eqs. 2-5 in the paper)
# ---------------------------------------------------------------------------


def iter_topk(x: jax.Array, k: int):
    """top_k via k iterative argmax passes (k <= 3 everywhere in the paper).

    Replaces jax.lax.top_k: jax lowers top_k to the dedicated `topk` HLO
    instruction, which the XLA 0.5.1 text parser (the version the rust
    `xla` crate binds) does not know. argmax lowers to plain reduces.
    """
    vals, idxs = [], []
    masked = x
    neg = jnp.finfo(x.dtype).min
    for _ in range(k):
        j = jnp.argmax(masked, axis=-1)
        v = jnp.take_along_axis(masked, j[..., None], axis=-1)[..., 0]
        idxs.append(j.astype(jnp.int32))
        vals.append(v)
        masked = jnp.where(jax.nn.one_hot(j, x.shape[-1], dtype=jnp.bool_), neg, masked)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)

def gate_logits(x: jax.Array, w_gate: jax.Array, w_noise=None, noise=None) -> jax.Array:
    """H(x): clean logits plus optional noise scaled by softplus(x.W_noise).

    x: [T, D]; w_gate/w_noise: [D, E]; noise: [T, E] standard normal draws
    (passed in explicitly so kernels stay deterministic functions).
    """
    logits = x @ w_gate
    if w_noise is not None and noise is not None:
        logits = logits + noise * jax.nn.softplus(x @ w_noise)
    return logits


def topk_mask(logits: jax.Array, k: int) -> jax.Array:
    """TopK-bar: keep top-k entries, -inf elsewhere. logits: [T, E]."""
    kth = iter_topk(logits, k)[0][..., -1:]  # [T, 1] k-th largest value
    neg = jnp.full_like(logits, -jnp.inf)
    return jnp.where(logits >= kth, logits, neg)


def topk_gating(logits: jax.Array, k: int):
    """Softmax over the top-k masked logits (Eq. 2).

    Returns (scores [T, E] with zeros outside top-k,
             indices [T, k] int32 sorted by descending score,
             weights [T, k] the matching scores).
    """
    masked = topk_mask(logits, k)
    scores = jax.nn.softmax(masked, axis=-1)
    weights, indices = iter_topk(scores, k)
    return scores, indices, weights


def load_balance_loss(logits: jax.Array, scores: jax.Array, k: int) -> jax.Array:
    """Switch-style auxiliary loss: E * sum_e fraction_e * prob_e.

    fraction_e = share of tokens whose top-k picks include expert e;
    prob_e = mean router probability mass on e (from full softmax).
    """
    e = logits.shape[-1]
    probs = jax.nn.softmax(logits, axis=-1)                # [T, E]
    picked = (scores > 0).astype(logits.dtype)             # [T, E]
    fraction = jnp.mean(picked, axis=0) / k                # [E]
    prob = jnp.mean(probs, axis=0)                         # [E]
    return e * jnp.sum(fraction * prob)


# ---------------------------------------------------------------------------
# GShard-style dispatch / combine (the data plane mirrored by rust moe/)
# ---------------------------------------------------------------------------

def dispatch_combine_masks(indices: jax.Array, weights: jax.Array,
                           n_experts: int, capacity: int):
    """Build dispatch [T, E, C] and combine [T, E, C] masks.

    Position-in-expert is assigned first-come-first-served per expert over
    the flattened (token, k) order; overflow beyond `capacity` is dropped —
    exactly the policy rust/src/moe/dispatch.rs implements.
    """
    t, k = indices.shape
    onehot = jax.nn.one_hot(indices, n_experts, dtype=jnp.int32)  # [T, k, E]
    # priority: earlier k-slot of earlier token wins
    flat = onehot.reshape(t * k, n_experts)
    pos = jnp.cumsum(flat, axis=0) - flat                        # [T*k, E]
    pos = pos.reshape(t, k, n_experts)
    in_cap = (pos < capacity) & (onehot > 0)
    pos_clipped = jnp.clip(pos, 0, capacity - 1)
    cap_onehot = jax.nn.one_hot(pos_clipped, capacity, dtype=jnp.float32)  # [T,k,E,C]
    disp = jnp.einsum("tke,tkec->tec", in_cap.astype(jnp.float32),
                      cap_onehot * in_cap[..., None].astype(jnp.float32))
    disp = jnp.clip(disp, 0.0, 1.0)
    comb = jnp.einsum("tk,tke,tkec->tec",
                      weights.astype(jnp.float32),
                      in_cap.astype(jnp.float32),
                      cap_onehot)
    return disp, comb


def moe_layer(x: jax.Array, w_gate: jax.Array, k: int, capacity: int,
              w1: jax.Array, b1: jax.Array, w2: jax.Array, b2: jax.Array,
              w_noise=None, noise=None):
    """Full reference MoE layer: gate -> dispatch -> expert_ffn -> combine.

    x: [T, D]. Returns (y [T, D], aux_loss scalar, scores [T, E]).
    """
    e = w_gate.shape[-1]
    logits = gate_logits(x, w_gate, w_noise, noise)
    scores, indices, weights = topk_gating(logits, k)
    disp, comb = dispatch_combine_masks(indices, weights, e, capacity)
    xe = jnp.einsum("td,tec->ecd", x, disp)                 # [E, C, D]
    ye = expert_ffn(xe, w1, b1, w2, b2)                     # [E, C, D]
    y = jnp.einsum("ecd,tec->td", ye, comb)                 # [T, D]
    aux = load_balance_loss(logits, scores, k)
    return y, aux, scores


# ---------------------------------------------------------------------------
# Causal multi-head attention core
# ---------------------------------------------------------------------------

def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              causal: bool = True) -> jax.Array:
    """softmax(QK^T/sqrt(d) [+ causal mask]) V per head.

    q, k, v: [H, T, Dh]. Returns [H, T, Dh].
    """
    dh = q.shape[-1]
    scores = jnp.einsum("htd,hsd->hts", q, k) / jnp.sqrt(dh).astype(q.dtype)
    if causal:
        t = q.shape[1]
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hts,hsd->htd", probs, v)
