"""Pallas kernel for the grouped expert FFN — the MoE compute hot-spot.

Forward AND backward are Pallas kernels wired together with jax.custom_vjp,
so the same kernel lowers into both the inference artifacts and the AOT
train-step HLO.

TPU mapping (DESIGN.md section "Hardware adaptation"): the grid iterates
(expert, token-block); each grid step keeps one expert's weights resident in
VMEM and streams `bc` tokens through the MXU (two [bc,D]x[D,F] / [bc,F]x[F,D]
matmuls). BlockSpec expresses the HBM->VMEM schedule that a CUDA
implementation would express with thread-block tiling + shared memory.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common, ref


def _fwd_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[0]                       # [BC, D]
    w1 = w1_ref[0]                     # [D, F]
    w2 = w2_ref[0]                     # [F, D]
    pre = x @ w1 + b1_ref[0]           # [BC, F]
    h = ref.gelu(pre)
    o_ref[0] = (h @ w2 + b2_ref[0]).astype(o_ref.dtype)


def _gelu_grad(pre):
    """d gelu(pre) / d pre for the tanh approximation used in ref.gelu."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(pre.dtype)
    u = c * (pre + 0.044715 * pre ** 3)
    t = jnp.tanh(u)
    du = c * (1.0 + 3 * 0.044715 * pre * pre)
    return 0.5 * (1.0 + t) + 0.5 * pre * (1.0 - t * t) * du


def _bwd_kernel(x_ref, w1_ref, b1_ref, w2_ref, g_ref,
                dx_ref, dw1_ref, db1_ref, dw2_ref, db2_ref):
    """Backward: recomputes h (activation rematerialization) and accumulates
    weight gradients across token-blocks (grid dim 1 revisits the same
    dw/db blocks; Pallas guarantees sequential grid order)."""
    cblk = pl.program_id(1)
    x = x_ref[0]                       # [BC, D]
    w1 = w1_ref[0]                     # [D, F]
    w2 = w2_ref[0]                     # [F, D]
    g = g_ref[0]                       # [BC, D]
    pre = x @ w1 + b1_ref[0]
    h = ref.gelu(pre)
    dh = g @ w2.T                      # [BC, F]
    dpre = dh * _gelu_grad(pre)        # [BC, F]
    dx_ref[0] = dpre @ w1.T

    @pl.when(cblk == 0)
    def _init():
        dw1_ref[0] = jnp.zeros_like(dw1_ref[0])
        db1_ref[0] = jnp.zeros_like(db1_ref[0])
        dw2_ref[0] = jnp.zeros_like(dw2_ref[0])
        db2_ref[0] = jnp.zeros_like(db2_ref[0])

    dw1_ref[0] += x.T @ dpre
    db1_ref[0] += jnp.sum(dpre, axis=0)
    dw2_ref[0] += h.T @ g
    db2_ref[0] += jnp.sum(g, axis=0)


def _specs(e, c, d, f, bc):
    grid = (e, c // bc)
    in_specs = [
        pl.BlockSpec((1, bc, d), lambda i, j: (i, j, 0)),   # x
        pl.BlockSpec((1, d, f), lambda i, j: (i, 0, 0)),    # w1
        pl.BlockSpec((1, f), lambda i, j: (i, 0)),          # b1
        pl.BlockSpec((1, f, d), lambda i, j: (i, 0, 0)),    # w2
        pl.BlockSpec((1, d), lambda i, j: (i, 0)),          # b2
    ]
    return grid, in_specs


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def expert_ffn(x, w1, b1, w2, b2, block_tokens=None, interpret=common.INTERPRET_DEFAULT):
    """Grouped expert FFN. x: [E, C, D]; weights per expert; returns [E, C, D]."""
    return _expert_ffn_fwd_only(x, w1, b1, w2, b2, block_tokens, interpret)


def _expert_ffn_fwd_only(x, w1, b1, w2, b2, block_tokens, interpret):
    e, c, d = x.shape
    f = w1.shape[-1]
    bc = block_tokens or common.ffn_block_tokens(c, d, f)
    grid, in_specs = _specs(e, c, d, f, bc)
    return pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bc, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c, d), x.dtype),
        interpret=interpret,
    )(x, w1, b1, w2, b2)


def _vjp_fwd(x, w1, b1, w2, b2, block_tokens, interpret):
    y = _expert_ffn_fwd_only(x, w1, b1, w2, b2, block_tokens, interpret)
    return y, (x, w1, b1, w2, b2)


def _vjp_bwd(block_tokens, interpret, res, g):
    x, w1, b1, w2, b2 = res
    e, c, d = x.shape
    f = w1.shape[-1]
    bc = block_tokens or common.ffn_block_tokens(c, d, f)
    grid, in_specs = _specs(e, c, d, f, bc)
    in_specs = in_specs[:4]  # x, w1, b1, w2 (b2 unused in bwd)
    in_specs.append(pl.BlockSpec((1, bc, d), lambda i, j: (i, j, 0)))  # g
    out_specs = [
        pl.BlockSpec((1, bc, d), lambda i, j: (i, j, 0)),   # dx
        pl.BlockSpec((1, d, f), lambda i, j: (i, 0, 0)),    # dw1 (accumulated)
        pl.BlockSpec((1, f), lambda i, j: (i, 0)),          # db1
        pl.BlockSpec((1, f, d), lambda i, j: (i, 0, 0)),    # dw2
        pl.BlockSpec((1, d), lambda i, j: (i, 0)),          # db2
    ]
    out_shape = [
        jax.ShapeDtypeStruct((e, c, d), x.dtype),
        jax.ShapeDtypeStruct((e, d, f), w1.dtype),
        jax.ShapeDtypeStruct((e, f), b1.dtype),
        jax.ShapeDtypeStruct((e, f, d), w2.dtype),
        jax.ShapeDtypeStruct((e, d), b2.dtype),
    ]
    dx, dw1, db1, dw2, db2 = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x, w1, b1, w2, g)
    return dx, dw1, db1, dw2, db2


expert_ffn.defvjp(_vjp_fwd, _vjp_bwd)
