"""Causal multi-head attention core as a Pallas kernel.

Grid iterates heads; one head's full [T, T] score tile lives in VMEM (the
sequence lengths used in this reproduction keep T <= 512, i.e. <= 1 MiB of
scores in f32 — within budget; the flash-tiled variant for long sequences is
analyzed in EXPERIMENTS.md section Perf but not needed at these shapes).

Backward recomputes probabilities in plain jnp inside a custom_vjp — the
recompute lowers into the same train-step HLO (rematerialization, no stored
probs), matching how the forward kernel avoids materializing probs in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common, ref


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, causal):
    q = q_ref[0]                      # [T, Dh]
    k = k_ref[0]
    v = v_ref[0]
    t, dh = q.shape
    scores = (q @ k.T) / jnp.sqrt(dh).astype(q.dtype)
    if causal:
        rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
        scores = jnp.where(rows >= cols, scores, jnp.finfo(scores.dtype).min)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = p @ v


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def attention(q, k, v, causal=True, interpret=common.INTERPRET_DEFAULT):
    """q, k, v: [H, T, Dh] -> [H, T, Dh]."""
    return _fwd_only(q, k, v, causal, interpret)


def _fwd_only(q, k, v, causal, interpret):
    h, t, dh = q.shape
    kern = functools.partial(_fwd_kernel, causal=causal)
    spec = pl.BlockSpec((1, t, dh), lambda i: (i, 0, 0))
    return pl.pallas_call(
        kern,
        grid=(h,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((h, t, dh), q.dtype),
        interpret=interpret,
    )(q, k, v)


def _vjp_fwd(q, k, v, causal, interpret):
    return _fwd_only(q, k, v, causal, interpret), (q, k, v)


def _vjp_bwd(causal, interpret, res, g):
    # jnp recompute backward (rematerialized probs), verified against
    # jax.grad of ref.attention in the tests.
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.attention(q_, k_, v_, causal), q, k, v)
    return vjp(g)


attention.defvjp(_vjp_fwd, _vjp_bwd)
