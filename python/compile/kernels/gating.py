"""Noisy top-k gating as a Pallas kernel.

The kernel consumes precomputed logits [T, E] (the gate matmul itself is a
trivially-fused GEMV that XLA handles; the irregular part — iterative top-k
selection + masked softmax — is what benefits from a hand-written kernel)
and produces:
    scores  [T, E]  softmax over top-k-masked logits (zeros elsewhere)
    indices [T, K]  int32 expert ids, descending score
    weights [T, K]  the matching combine weights

Gradients flow through `scores` only (indices are integral); the custom_vjp
backward differentiates the reference masked-softmax at fixed mask — the
same gradient the standard top-k MoE uses.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common, ref


def _kernel(logits_ref, scores_ref, idx_ref, w_ref, *, k):
    logits = logits_ref[...]                         # [BT, E]
    bt, e = logits.shape
    neg = jnp.finfo(logits.dtype).min
    masked = logits
    picked = jnp.zeros((bt, e), dtype=jnp.bool_)
    idxs = []
    # iterative argmax: k passes (k <= 3 in every paper config)
    for _ in range(k):
        j = jnp.argmax(masked, axis=-1)              # [BT]
        idxs.append(j.astype(jnp.int32))
        onehot = jax.nn.one_hot(j, e, dtype=jnp.bool_)
        picked = picked | onehot
        masked = jnp.where(onehot, neg, masked)
    # softmax over the picked set
    sel = jnp.where(picked, logits, neg)
    m = jnp.max(sel, axis=-1, keepdims=True)
    ex = jnp.where(picked, jnp.exp(sel - m), 0.0)
    scores = ex / jnp.sum(ex, axis=-1, keepdims=True)
    scores_ref[...] = scores.astype(logits.dtype)
    rows = jnp.arange(bt)
    for kk in range(k):
        idx_ref[:, kk] = idxs[kk]
        w_ref[:, kk] = scores[rows, idxs[kk]]


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def topk_gating(logits, k, block_tokens=None, interpret=common.INTERPRET_DEFAULT):
    return _fwd_only(logits, k, block_tokens, interpret)


def _fwd_only(logits, k, block_tokens, interpret):
    t, e = logits.shape
    bt = block_tokens or common.largest_divisor_leq(t, 512)
    kern = functools.partial(_kernel, k=k)
    scores, idx, w = pl.pallas_call(
        kern,
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((bt, e), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bt, e), lambda i: (i, 0)),
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, e), logits.dtype),
            jax.ShapeDtypeStruct((t, k), jnp.int32),
            jax.ShapeDtypeStruct((t, k), logits.dtype),
        ],
        interpret=interpret,
    )(logits)
    return scores, idx, w


def _vjp_fwd(logits, k, block_tokens, interpret):
    out = _fwd_only(logits, k, block_tokens, interpret)
    return out, (logits,)


def _vjp_bwd(k, block_tokens, interpret, res, g):
    (logits,) = res
    gscores, _, gweights = g

    def f(lg):
        scores, idx, w = ref.topk_gating(lg, k)
        return scores, w

    _, vjp = jax.vjp(f, logits)
    (dlogits,) = vjp((gscores, gweights))
    return (dlogits,)


topk_gating.defvjp(_vjp_fwd, _vjp_bwd)
