"""Fused LayerNorm Pallas kernel (fwd + bwd via custom_vjp).

Grid tiles the token axis; gamma/beta stay resident. The backward kernel
accumulates dgamma/dbeta across token-blocks in the same sequential-grid
pattern as expert_ffn's weight gradients.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common

EPS = 1e-5


def _fwd_kernel(x_ref, g_ref, b_ref, o_ref):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    xhat = (x - mu) * jax.lax.rsqrt(var + EPS)
    o_ref[...] = xhat * g_ref[...] + b_ref[...]


def _bwd_kernel(x_ref, g_ref, dy_ref, dx_ref, dg_ref, db_ref):
    tblk = pl.program_id(0)
    x = x_ref[...]
    dy = dy_ref[...]
    gamma = g_ref[...]
    d = x.shape[-1]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + EPS)
    xhat = (x - mu) * rstd
    dxhat = dy * gamma
    # standard LN backward
    dx = (dxhat - jnp.mean(dxhat, axis=-1, keepdims=True)
          - xhat * jnp.mean(dxhat * xhat, axis=-1, keepdims=True)) * rstd
    dx_ref[...] = dx

    @pl.when(tblk == 0)
    def _init():
        dg_ref[...] = jnp.zeros_like(dg_ref[...])
        db_ref[...] = jnp.zeros_like(db_ref[...])

    dg_ref[...] += jnp.sum(dy * xhat, axis=0)
    db_ref[...] += jnp.sum(dy, axis=0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def layernorm(x, gamma, beta, block_tokens=None, interpret=common.INTERPRET_DEFAULT):
    """LayerNorm over the last axis. x: [T, D]; gamma/beta: [D]."""
    return _fwd_only(x, gamma, beta, block_tokens, interpret)


def _fwd_only(x, gamma, beta, block_tokens, interpret):
    t, d = x.shape
    bt = block_tokens or common.largest_divisor_leq(t, 256)
    return pl.pallas_call(
        _fwd_kernel,
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=interpret,
    )(x, gamma, beta)


def _vjp_fwd(x, gamma, beta, block_tokens, interpret):
    return _fwd_only(x, gamma, beta, block_tokens, interpret), (x, gamma)


def _vjp_bwd(block_tokens, interpret, res, dy):
    x, gamma = res
    t, d = x.shape
    bt = block_tokens or common.largest_divisor_leq(t, 256)
    dx, dg, db = pl.pallas_call(
        _bwd_kernel,
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), x.dtype),
            jax.ShapeDtypeStruct((d,), gamma.dtype),
            jax.ShapeDtypeStruct((d,), gamma.dtype),
        ],
        interpret=interpret,
    )(x, gamma, dy)
    return dx, dg, db


layernorm.defvjp(_vjp_fwd, _vjp_bwd)
