"""L2: the ScMoE-family transformer in JAX, composed from L1 Pallas kernels.

Every architecture in the paper is a pure function of (params, inputs):
standard top-k MoE, shared-expert MoE, ScMoE Pos-1/2/3, ScMoE-2, DGMoE and
DGMoE-Share — see config.ARCHS. Parameters are a flat, ordered list of
named tensors (`param_specs`) so the Rust runtime can hold them as opaque
device buffers.

The model never runs at serving time: `aot.py` lowers the jitted functions
to HLO text once, and the Rust coordinator executes the artifacts.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import attention as attn_k
from .kernels import expert_ffn as effn_k
from .kernels import gating as gate_k
from .kernels import layernorm as ln_k
from .kernels import ref

Params = Dict[str, jax.Array]

# Stats layout per MoE block for the Fig.11 analysis (see `stats` below).
STATS_PER_MOE = 4
STATS_FIELDS = ("repeat_frac", "l2_dist", "score_prev", "score_cur")


# ---------------------------------------------------------------------------
# Parameter specification (the Python<->Rust interface contract)
# ---------------------------------------------------------------------------

def _ffn_specs(prefix: str, d: int, f: int):
    return [
        (f"{prefix}.w1", (d, f)),
        (f"{prefix}.b1", (f,)),
        (f"{prefix}.w2", (f, d)),
        (f"{prefix}.b2", (d,)),
    ]


def _moe_param_block(cfg: ModelConfig, b: int):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    specs = [(f"blk{b}.moe.wg", (d, e))]
    if cfg.noisy_gate:
        specs.append((f"blk{b}.moe.wn", (d, e)))
    specs += [
        (f"blk{b}.moe.w1", (e, d, f)),
        (f"blk{b}.moe.b1", (e, f)),
        (f"blk{b}.moe.w2", (e, f, d)),
        (f"blk{b}.moe.b2", (e, d)),
    ]
    return specs


def moe_share_source(cfg: ModelConfig, b: int) -> int:
    """For dgmoe_share, MoE params of pair p>0,odd reuse pair p-1's block."""
    if cfg.arch != "dgmoe_share":
        return b
    pair = b // 2
    if pair % 2 == 1:
        return b - 2
    return b


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the single flattening order used by
    init/train/eval artifacts and recorded in manifest.json."""
    d, f = cfg.d_model, cfg.d_ff
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed.tok", (cfg.vocab_size, d)),
        ("embed.pos", (cfg.seq_len, d)),
    ]
    for b in range(cfg.n_blocks):
        specs += [
            (f"blk{b}.ln1.g", (d,)), (f"blk{b}.ln1.b", (d,)),
            (f"blk{b}.attn.wqkv", (d, 3 * d)), (f"blk{b}.attn.bqkv", (3 * d,)),
            (f"blk{b}.attn.wo", (d, d)), (f"blk{b}.attn.bo", (d,)),
            (f"blk{b}.ln2.g", (d,)), (f"blk{b}.ln2.b", (d,)),
        ]
        is_moe = (b % 2 == 1) and cfg.arch != "dense"
        if not is_moe:
            specs += _ffn_specs(f"blk{b}.mlp", d, f)
        else:
            if moe_share_source(cfg, b) == b:
                specs += _moe_param_block(cfg, b)
            if cfg.uses_shortcut:
                # dedicated LN for the shortcut input to the MoE module
                specs += [(f"blk{b}.lnsc.g", (d,)), (f"blk{b}.lnsc.b", (d,))]
            if cfg.has_shared_expert:
                specs += _ffn_specs(f"blk{b}.se", d, f)
                if cfg.se_gate:
                    specs.append((f"blk{b}.segate.w", (d,)))
    specs += [("final_ln.g", (d,)), ("final_ln.b", (d,))]
    if cfg.task == "lm":
        specs.append(("head.w", (d, cfg.vocab_size)))
    else:
        specs += [("head.w", (d, cfg.n_classes)), ("head.b", (cfg.n_classes,))]
    return specs


def param_count(cfg: ModelConfig) -> int:
    total = 0
    for _, shape in param_specs(cfg):
        n = 1
        for s in shape:
            n *= s
        total += n
    return total


def init_params(cfg: ModelConfig, key: jax.Array) -> List[jax.Array]:
    """Deterministic initialization in param_specs order (scaled normal for
    matrices, ones/zeros for norms and biases)."""
    out = []
    specs = param_specs(cfg)
    keys = jax.random.split(key, len(specs))
    for (name, shape), k in zip(specs, keys):
        if name.endswith(".g") or name.endswith("segate.w"):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(".b") or name.endswith((".b1", ".b2", ".bqkv", ".bo")):
            out.append(jnp.zeros(shape, jnp.float32))
        elif name.endswith((".wg", ".wn")):
            out.append(0.02 * jax.random.normal(k, shape, jnp.float32))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = 0.02 if "embed" in name else 1.0 / jnp.sqrt(fan_in)
            out.append(std * jax.random.normal(k, shape, jnp.float32))
    return out


def to_dict(cfg: ModelConfig, flat: List[jax.Array]) -> Params:
    return {name: t for (name, _), t in zip(param_specs(cfg), flat)}


def to_flat(cfg: ModelConfig, p: Params) -> List[jax.Array]:
    return [p[name] for name, _ in param_specs(cfg)]


# ---------------------------------------------------------------------------
# Sub-layers
# ---------------------------------------------------------------------------

def _ln2d(x: jax.Array, g: jax.Array, b: jax.Array) -> jax.Array:
    """LayerNorm over last dim for [B, S, D] via the Pallas kernel."""
    bsz, s, d = x.shape
    return ln_k.layernorm(x.reshape(bsz * s, d), g, b).reshape(bsz, s, d)


def attn_sublayer(cfg: ModelConfig, p: Params, b: int, x: jax.Array) -> jax.Array:
    """Pre-norm causal self-attention with residual. x: [B, S, D]."""
    bsz, s, d = x.shape
    h = _ln2d(x, p[f"blk{b}.ln1.g"], p[f"blk{b}.ln1.b"])
    qkv = h @ p[f"blk{b}.attn.wqkv"] + p[f"blk{b}.attn.bqkv"]   # [B,S,3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def per_example(q1, k1, v1):
        # [S, D] -> [H, S, Dh]
        def heads(t):
            return t.reshape(s, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)
        o = attn_k.attention(heads(q1), heads(k1), heads(v1),
                             causal=(cfg.task == "lm"))
        return o.transpose(1, 0, 2).reshape(s, d)

    o = jax.vmap(per_example)(q, k, v)
    return x + o @ p[f"blk{b}.attn.wo"] + p[f"blk{b}.attn.bo"]


def ffn_sublayer(p: Params, prefix: str, x: jax.Array,
                 ln_g: jax.Array, ln_b: jax.Array) -> jax.Array:
    """Pre-norm MLP with residual, using the expert-FFN kernel with E=1
    (one 'expert' = the dense MLP — same hot-path code)."""
    bsz, s, d = x.shape
    h = _ln2d(x, ln_g, ln_b).reshape(1, bsz * s, d)
    y = effn_k.expert_ffn(
        h,
        p[f"{prefix}.w1"][None], p[f"{prefix}.b1"][None],
        p[f"{prefix}.w2"][None], p[f"{prefix}.b2"][None],
    )[0].reshape(bsz, s, d)
    return x + y


def _se_output(cfg: ModelConfig, p: Params, b: int, x: jax.Array) -> jax.Array:
    """Shared-expert branch output (no residual add)."""
    bsz, s, d = x.shape
    h = _ln2d(x, p[f"blk{b}.ln2.g"], p[f"blk{b}.ln2.b"])
    y = effn_k.expert_ffn(
        h.reshape(1, bsz * s, d),
        p[f"blk{b}.se.w1"][None], p[f"blk{b}.se.b1"][None],
        p[f"blk{b}.se.w2"][None], p[f"blk{b}.se.b2"][None],
    )[0].reshape(bsz, s, d)
    if cfg.se_gate:
        # Appendix A.3: per-token scalar coefficient from a linear gate
        coef = jax.nn.sigmoid(h @ p[f"blk{b}.segate.w"])    # [B, S]
        y = y * coef[..., None]
    return y


def _moe_apply(cfg: ModelConfig, p: Params, b: int, h2d: jax.Array, k: int,
               noise: jax.Array | None):
    """Run the gate + dispatch + grouped-expert-FFN + combine on [T, D]
    (already layer-normed). Returns (y [T,D], aux scalar, logits, scores,
    indices, weights)."""
    src = moe_share_source(cfg, b)
    wg = p[f"blk{src}.moe.wg"]
    wn = p.get(f"blk{src}.moe.wn") if cfg.noisy_gate else None
    logits = ref.gate_logits(h2d, wg, wn, noise)
    scores, idx, w = gate_k.topk_gating(logits, k)
    t = h2d.shape[0]
    cap = cfg.expert_capacity(t)
    disp, comb = ref.dispatch_combine_masks(idx, w, cfg.n_experts, cap)
    xe = jnp.einsum("td,tec->ecd", h2d, disp)
    ye = effn_k.expert_ffn(
        xe,
        p[f"blk{src}.moe.w1"], p[f"blk{src}.moe.b1"],
        p[f"blk{src}.moe.w2"], p[f"blk{src}.moe.b2"],
    )
    y = jnp.einsum("ecd,tec->td", ye, comb)
    aux = ref.load_balance_loss(logits, scores, k)
    return y, aux, logits, scores, idx, w


# ---------------------------------------------------------------------------
# Full forward
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, flat_params: List[jax.Array], tokens: jax.Array,
            noise_key: jax.Array | None = None, train: bool = False):
    """Forward pass.

    tokens: int32 [B, S]. Returns dict with:
      logits      [B, S, vocab] (lm) or [B, n_classes] (cls)
      aux         scalar MoE load-balance loss (already coef-weighted)
      stats       [n_moe_blocks, 4] Fig.11 instrumentation
      selections  [n_moe_blocks, T, k] expert choices (for offload driver)
    """
    p = to_dict(cfg, flat_params)
    bsz, s = tokens.shape
    d = cfg.d_model
    x = p["embed.tok"][tokens] + p["embed.pos"][None, :s, :]

    aux_total = jnp.zeros((), jnp.float32)
    stats_rows = []
    selections = []
    k = cfg.top_k

    prev_in = x       # input of preceding block  (Pos-3)
    prev_mid = x      # post-attention intermediate of preceding block (Pos-2)
    prev_out = x      # output of preceding block (Pos-1)

    moe_i = 0
    for b in range(cfg.n_blocks):
        block_in = x
        x = attn_sublayer(cfg, p, b, x)
        mid = x
        is_moe = (b % 2 == 1) and cfg.arch != "dense"
        if not is_moe:
            x = ffn_sublayer(p, f"blk{b}.mlp", x,
                             p[f"blk{b}.ln2.g"], p[f"blk{b}.ln2.b"])
        else:
            t = bsz * s
            if noise_key is not None and cfg.noisy_gate and train:
                nk = jax.random.fold_in(noise_key, b)
                noise = jax.random.normal(nk, (t, cfg.n_experts))
            else:
                noise = None

            if cfg.arch in ("top1", "top2", "top3"):
                h2d = _ln2d(x, p[f"blk{b}.ln2.g"], p[f"blk{b}.ln2.b"]).reshape(t, d)
                y, aux, logits, scores, idx, w = _moe_apply(cfg, p, b, h2d, k, noise)
                x = x + y.reshape(bsz, s, d)
                stats_rows.append(_stats_plain(logits, w))
            elif cfg.arch == "shared":
                h2d = _ln2d(x, p[f"blk{b}.ln2.g"], p[f"blk{b}.ln2.b"]).reshape(t, d)
                y, aux, logits, scores, idx, w = _moe_apply(cfg, p, b, h2d, 1, noise)
                x = x + _se_output(cfg, p, b, x) + y.reshape(bsz, s, d)
                stats_rows.append(_stats_plain(logits, w))
            elif cfg.arch in ("scmoe_pos1", "scmoe", "scmoe_pos3", "scmoe2"):
                src = {"scmoe_pos1": prev_out, "scmoe": prev_mid,
                       "scmoe_pos3": prev_in, "scmoe2": prev_mid}[cfg.arch]
                h_sc = _ln2d(src, p[f"blk{b}.lnsc.g"], p[f"blk{b}.lnsc.b"]).reshape(t, d)
                y, aux, logits, scores, idx, w = _moe_apply(cfg, p, b, h_sc, k, noise)
                x = x + _se_output(cfg, p, b, x) + y.reshape(bsz, s, d)
                # Fig.11 (a)/(b): same-gate selection on cur vs prev reps
                h_cur = _ln2d(x, p[f"blk{b}.ln2.g"], p[f"blk{b}.ln2.b"]).reshape(t, d)
                stats_rows.append(_stats_shortcut(cfg, p, b, h_sc, h_cur, logits, w))
            elif cfg.arch in ("dgmoe", "dgmoe_share"):
                h_sc = _ln2d(prev_mid, p[f"blk{b}.lnsc.g"],
                             p[f"blk{b}.lnsc.b"]).reshape(t, d)
                h_cur = _ln2d(x, p[f"blk{b}.ln2.g"], p[f"blk{b}.ln2.b"]).reshape(t, d)
                y, aux, idx, w, st = _dgmoe_apply(cfg, p, b, h_sc, h_cur, noise)
                x = x + y.reshape(bsz, s, d)
                stats_rows.append(st)
            else:  # dense handled above
                raise AssertionError(cfg.arch)
            aux_total = aux_total + aux
            selections.append(idx)
            moe_i += 1
        prev_in = block_in
        prev_mid = mid
        prev_out = x

    x = _ln2d(x, p["final_ln.g"], p["final_ln.b"])
    if cfg.task == "lm":
        logits_out = x @ p["head.w"]
    else:
        pooled = jnp.mean(x, axis=1)
        logits_out = pooled @ p["head.w"] + p["head.b"]

    stats = (jnp.stack(stats_rows) if stats_rows
             else jnp.zeros((0, STATS_PER_MOE), jnp.float32))
    sel = (jnp.stack(selections) if selections
           else jnp.zeros((0, bsz * s, max(k, 1)), jnp.int32))
    return {
        "logits": logits_out,
        "aux": cfg.moe_loss_coef * aux_total,
        "stats": stats,
        "selections": sel,
    }


def _stats_plain(logits: jax.Array, w: jax.Array) -> jax.Array:
    """Stats row for non-shortcut MoE: only the mean top-1 score is
    meaningful; repeat/L2 fields are zero."""
    return jnp.stack([
        jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
        jnp.zeros((), jnp.float32), jnp.mean(w[:, 0]),
    ])


def _stats_shortcut(cfg, p, b, h_prev, h_cur, logits_prev, w_prev) -> jax.Array:
    """Fig.11 instrumentation: apply the same gate to the current-layer
    representation and compare selections/representations."""
    src = moe_share_source(cfg, b)
    wg = p[f"blk{src}.moe.wg"]
    logits_cur = h_cur @ wg
    top1_prev = jnp.argmax(logits_prev, axis=-1)
    top1_cur = jnp.argmax(logits_cur, axis=-1)
    repeat = jnp.mean((top1_prev == top1_cur).astype(jnp.float32))
    l2 = jnp.mean(jnp.linalg.norm(h_prev - h_cur, axis=-1))
    scores_cur = jax.nn.softmax(logits_cur, axis=-1)
    return jnp.stack([
        repeat, l2, jnp.mean(w_prev[:, 0]),
        jnp.mean(jnp.max(scores_cur, axis=-1)),
    ])


def _dgmoe_apply(cfg, p, b, h_prev, h_cur, noise):
    """DoubleGating MoE (Appendix A.2): top-1 on the preceding-layer rep and
    top-1 on the current-layer rep, constrained to pick *distinct* experts
    (if equal, the current layer takes its second-best)."""
    src = moe_share_source(cfg, b)
    wg = p[f"blk{src}.moe.wg"]
    wn = p.get(f"blk{src}.moe.wn") if cfg.noisy_gate else None
    t = h_prev.shape[0]
    e = cfg.n_experts

    logits_prev = ref.gate_logits(h_prev, wg, wn, noise)
    logits_cur = h_cur @ wg
    _, idx_p, w_p = gate_k.topk_gating(logits_prev, 1)
    scores2, idx2, w2 = gate_k.topk_gating(logits_cur, 2)
    same = idx2[:, 0] == idx_p[:, 0]
    idx_c = jnp.where(same, idx2[:, 1], idx2[:, 0])[:, None]
    w_c = jnp.ones_like(w_p)  # top-1 masked softmax weight == 1

    cap = cfg.expert_capacity(t)
    idx = jnp.concatenate([idx_p, idx_c], axis=1)          # [T, 2]
    w = jnp.concatenate([w_p, w_c], axis=1)
    disp, comb = ref.dispatch_combine_masks(idx, w, e, cap)
    # prev tokens go through slot 0 routing, cur through slot 1 — dispatch
    # masks mix them, so dispatch each representation with its own mask.
    disp_p, comb_p = ref.dispatch_combine_masks(idx_p, w_p, e, cap)
    disp_c, comb_c = ref.dispatch_combine_masks(idx_c, w_c, e, cap)
    xe = (jnp.einsum("td,tec->ecd", h_prev, disp_p)
          + jnp.einsum("td,tec->ecd", h_cur, disp_c))
    # NB: capacity slots are assigned independently per mask, so a slot can
    # be shared only if both masks routed different tokens to it; to keep
    # the semantics exact we run the experts twice (prev and cur batches).
    ye_p = effn_k.expert_ffn(
        jnp.einsum("td,tec->ecd", h_prev, disp_p),
        p[f"blk{src}.moe.w1"], p[f"blk{src}.moe.b1"],
        p[f"blk{src}.moe.w2"], p[f"blk{src}.moe.b2"])
    ye_c = effn_k.expert_ffn(
        jnp.einsum("td,tec->ecd", h_cur, disp_c),
        p[f"blk{src}.moe.w1"], p[f"blk{src}.moe.b1"],
        p[f"blk{src}.moe.w2"], p[f"blk{src}.moe.b2"])
    y = (jnp.einsum("ecd,tec->td", ye_p, comb_p)
         + jnp.einsum("ecd,tec->td", ye_c, comb_c))

    s_prev, _, _ = ref.topk_gating(logits_prev, 1)
    aux = ref.load_balance_loss(logits_prev, s_prev, 1) \
        + ref.load_balance_loss(logits_cur, scores2, 2)

    # Fig.11 (c)/(d): gating scores of prev and cur selections
    probs_prev = jax.nn.softmax(logits_prev, axis=-1)
    probs_cur = jax.nn.softmax(logits_cur, axis=-1)
    rows = jnp.arange(t)
    st = jnp.stack([
        jnp.mean(same.astype(jnp.float32)),
        jnp.mean(jnp.linalg.norm(h_prev - h_cur, axis=-1)),
        jnp.mean(probs_prev[rows, idx_p[:, 0]]),
        jnp.mean(probs_cur[rows, idx_c[:, 0]]),
    ])
    return y, aux, idx, w, st
