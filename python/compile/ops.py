"""Per-operator functions lowered into standalone artifacts.

These are the units the Rust coordinator schedules (Fig. 5/6): the backbone
stream (attn_op / mlp_op / se_op) and the MoE stream (gate_op / expert_op),
with encode / All-to-All / decode living entirely in Rust. One artifact per
operator per shape profile; the calibration harness measures their wallclock
to ground the discrete-event simulator.

`moe_fused_op` runs the whole MoE layer in one HLO — the numerics oracle the
Rust-orchestrated distributed path is integration-tested against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import attention as attn_k
from .kernels import expert_ffn as effn_k
from .kernels import gating as gate_k
from .kernels import layernorm as ln_k
from .kernels import ref


def attn_op(cfg: ModelConfig, x, ln_g, ln_b, wqkv, bqkv, wo, bo):
    """Pre-norm causal attention sub-layer with residual. x: [T, D] (one
    sequence; the coordinator batches sequences by stacking calls)."""
    t, d = x.shape
    h = ln_k.layernorm(x, ln_g, ln_b)
    qkv = h @ wqkv + bqkv
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(z):
        return z.reshape(t, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)

    o = attn_k.attention(heads(q), heads(k), heads(v), causal=(cfg.task == "lm"))
    o = o.transpose(1, 0, 2).reshape(t, d)
    return x + o @ wo + bo


def mlp_op(cfg: ModelConfig, x, ln_g, ln_b, w1, b1, w2, b2):
    """Pre-norm dense FFN sub-layer with residual. x: [T, D]."""
    h = ln_k.layernorm(x, ln_g, ln_b)
    y = effn_k.expert_ffn(h[None], w1[None], b1[None], w2[None], b2[None])[0]
    return x + y


def se_op(cfg: ModelConfig, x, ln_g, ln_b, w1, b1, w2, b2, segate_w):
    """Shared-expert branch (returns the SE contribution, no residual)."""
    h = ln_k.layernorm(x, ln_g, ln_b)
    y = effn_k.expert_ffn(h[None], w1[None], b1[None], w2[None], b2[None])[0]
    coef = jax.nn.sigmoid(h @ segate_w)
    return y * coef[:, None]


def gate_op(cfg: ModelConfig, x, ln_g, ln_b, wg, k: int):
    """Gate routing on the (layer-normed) MoE input: returns int32 indices
    [T, k] and combine weights [T, k]. Deterministic (inference path)."""
    h = ln_k.layernorm(x, ln_g, ln_b)
    logits = h @ wg
    _, idx, w = gate_k.topk_gating(logits, k)
    return h, idx, w


def expert_op(cfg: ModelConfig, xe, w1, b1, w2, b2):
    """One expert's FFN over its capacity buffer. xe: [C, D]."""
    return effn_k.expert_ffn(xe[None], w1[None], b1[None], w2[None], b2[None])[0]


def experts_op(cfg: ModelConfig, xe, w1, b1, w2, b2):
    """All local experts' FFN over dispatched buffers. xe: [E, C, D]."""
    return effn_k.expert_ffn(xe, w1, b1, w2, b2)


def moe_fused_op(cfg: ModelConfig, x, ln_g, ln_b, wg, w1, b1, w2, b2, k: int,
                 capacity: int):
    """Entire MoE layer (gate+dispatch+experts+combine) in one HLO: the
    numerics oracle for the Rust-orchestrated path. x: [T, D] un-normed."""
    h = ln_k.layernorm(x, ln_g, ln_b)
    y, aux, _ = ref.moe_layer(h, wg, k, capacity, w1, b1, w2, b2)
    return y


def ops_init(cfg: ModelConfig, seed):
    """Weights for one Block-MLP + Block-MoE pair at ops shapes (stacked
    expert weights; Rust slices per-expert contiguously)."""
    key = jax.random.PRNGKey(seed)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 12)
    sd = 1.0 / jnp.sqrt(d)
    sf = 1.0 / jnp.sqrt(f)
    return (
        jnp.ones((d,)), jnp.zeros((d,)),                    # ln_g, ln_b
        sd * jax.random.normal(ks[0], (d, 3 * d)), jnp.zeros((3 * d,)),
        sd * jax.random.normal(ks[1], (d, d)), jnp.zeros((d,)),
        sd * jax.random.normal(ks[2], (d, f)), jnp.zeros((f,)),   # mlp w1,b1
        sf * jax.random.normal(ks[3], (f, d)), jnp.zeros((d,)),   # mlp w2,b2
        0.02 * jax.random.normal(ks[4], (d, e)),                  # wg
        sd * jax.random.normal(ks[5], (e, d, f)), jnp.zeros((e, f)),
        sf * jax.random.normal(ks[6], (e, f, d)), jnp.zeros((e, d)),
        jnp.ones((d,)),                                           # segate_w
    )
