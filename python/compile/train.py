"""Training/eval/inference step functions lowered by aot.py.

The Rust driver owns the loop; these functions are single steps with a flat
tensor interface:

  train_step(params..., m..., v..., step, tokens, targets, seed)
      -> (params'..., m'..., v'..., loss, aux, acc, stats)
  eval_step(params..., tokens, targets) -> (loss, acc)
  infer_step(params..., tokens) -> (logits, selections)
  init(seed) -> (params...,)

Adam with inverse-sqrt warmup schedule (paper Appendix Table 8). Optimizer
state lives on-device between steps — Rust feeds the outputs of step t
straight back into step t+1 as PjRtBuffers (no host round-trip).
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from . import model
from .config import ModelConfig


def loss_fn(cfg: ModelConfig, flat_params, tokens, targets, noise_key, train):
    out = model.forward(cfg, flat_params, tokens, noise_key=noise_key, train=train)
    logits = out["logits"]
    if cfg.task == "lm":
        # next-token CE; targets: [B, S]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        loss = jnp.mean(nll)
        acc = jnp.mean((jnp.argmax(logits, -1) == targets).astype(jnp.float32))
    else:
        # sequence classification; targets: [B]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[:, None], axis=-1)[:, 0]
        loss = jnp.mean(nll)
        acc = jnp.mean((jnp.argmax(logits, -1) == targets).astype(jnp.float32))
    return loss + out["aux"], (loss, out["aux"], acc, out["stats"])


def lr_schedule(cfg: ModelConfig, step: jax.Array) -> jax.Array:
    """inverse_sqrt with linear warmup (fairseq-style)."""
    step_f = step.astype(jnp.float32) + 1.0
    warm = jnp.asarray(float(cfg.warmup_steps), jnp.float32)
    warmup_lr = cfg.learning_rate * step_f / warm
    decay_lr = cfg.learning_rate * jnp.sqrt(warm) / jnp.sqrt(step_f)
    return jnp.where(step_f < warm, warmup_lr, decay_lr)


def train_step(cfg: ModelConfig, params: List[jax.Array], m: List[jax.Array],
               v: List[jax.Array], step: jax.Array, tokens: jax.Array,
               targets: jax.Array, seed: jax.Array):
    """One Adam step. All lists are in model.param_specs order."""
    noise_key = jax.random.PRNGKey(seed)
    grad_fn = jax.value_and_grad(
        lambda fp: loss_fn(cfg, fp, tokens, targets, noise_key, True),
        has_aux=True)
    (total, (loss, aux, acc, stats)), grads = grad_fn(params)

    lr = lr_schedule(cfg, step)
    b1, b2, eps = cfg.adam_b1, cfg.adam_b2, cfg.adam_eps
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - jnp.power(b1, t)
    bc2 = 1.0 - jnp.power(b2, t)

    new_p, new_m, new_v = [], [], []
    for pi, mi, vi, gi in zip(params, m, v, grads):
        mi = b1 * mi + (1 - b1) * gi
        vi = b2 * vi + (1 - b2) * gi * gi
        mhat = mi / bc1
        vhat = vi / bc2
        upd = lr * mhat / (jnp.sqrt(vhat) + eps)
        if cfg.weight_decay > 0.0:
            upd = upd + lr * cfg.weight_decay * pi
        new_p.append(pi - upd)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v, loss, aux, acc, stats


def eval_step(cfg: ModelConfig, params: List[jax.Array], tokens: jax.Array,
              targets: jax.Array):
    _, (loss, aux, acc, _) = loss_fn(cfg, params, tokens, targets, None, False)
    return loss, acc


def infer_step(cfg: ModelConfig, params: List[jax.Array], tokens: jax.Array):
    out = model.forward(cfg, params, tokens, noise_key=None, train=False)
    return out["logits"], out["selections"]


def init(cfg: ModelConfig, seed: jax.Array):
    key = jax.random.PRNGKey(seed)
    return model.init_params(cfg, key)


def train_step_n(cfg: ModelConfig, params, m, v, step0, tokens_n, targets_n,
                 seed: jax.Array, n: int):
    """`n` fused training steps via lax.scan — amortizes the PJRT host
    round-trip (the executable returns one tuple literal per call, so state
    crossing the boundary once per N steps instead of once per step).

    tokens_n/targets_n: [n, B, S]. Returns (params, m, v, losses [n],
    accs [n]).
    """

    def body(carry, xs):
        p, mm, vv, step = carry
        tokens, targets, i = xs
        p2, m2, v2, loss, aux, acc, _stats = train_step(
            cfg, p, mm, vv, step, tokens, targets, seed + i)
        return (p2, m2, v2, step + 1), (loss, acc)

    idx = jnp.arange(n, dtype=jnp.int32)
    (p, mm, vv, _), (losses, accs) = jax.lax.scan(
        body, (list(params), list(m), list(v), step0),
        (tokens_n, targets_n, idx))
    return p, mm, vv, losses, accs
