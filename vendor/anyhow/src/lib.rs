//! Offline, API-compatible subset of the `anyhow` crate.
//!
//! The build environment has no registry access, so this in-tree shim
//! provides exactly the surface the workspace uses: `Error`, `Result`,
//! the `anyhow!` / `bail!` / `ensure!` macros, and the `Context` extension
//! trait for `Result` and `Option`. Error values carry a context chain
//! (outermost first) rendered as `outer: inner: root`.

use std::fmt;

/// A string-backed error with a context chain, convertible from any
/// `std::error::Error`.
pub struct Error {
    /// Context chain, outermost context first, root cause last.
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context layer (the `.context(...)` operation).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root cause message (innermost layer).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // Fold the source chain into the context chain.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` specialized to [`Error`], matching `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod private {
    /// Dispatch trait so `Context` covers both `Result<T, E: StdError>`
    /// and `Result<T, anyhow::Error>` without overlapping impls
    /// (the same structure the real `anyhow` uses).
    pub trait IntoAnyhow {
        fn into_anyhow(self) -> super::Error;
    }

    impl<E> IntoAnyhow for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_anyhow(self) -> super::Error {
            super::Error::from(self)
        }
    }

    impl IntoAnyhow for super::Error {
        fn into_anyhow(self) -> super::Error {
            self
        }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`, matching `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: private::IntoAnyhow> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_anyhow().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_anyhow().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            io_err()?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "gone");
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = io_err().context("opening file").unwrap_err();
        assert_eq!(e.to_string(), "opening file: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let r: Result<()> = Err(Error::msg("root"));
        assert_eq!(r.context("outer").unwrap_err().to_string(), "outer: root");
        let o: Option<u32> = None;
        assert_eq!(o.with_context(|| "missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky 7");
        assert_eq!(f(11).unwrap_err().to_string(), "x too big: 11");
        let e = anyhow!(String::from("from a string"));
        assert_eq!(e.to_string(), "from a string");
    }
}
