//! Offline stub of the XLA/PJRT bindings used by the `scmoe` runtime layer.
//!
//! The real backend links `xla_extension` (PJRT CPU plugin), which is not
//! available in this build environment. This stub keeps the exact API
//! surface the runtime uses so the crate compiles and unit tests run;
//! `PjRtClient::cpu()` returns an error, and every artifact-gated test,
//! example, and subcommand that would need a real client skips cleanly
//! (they all check for compiled artifacts before constructing the engine).
//!
//! Host-side `Literal` containers are fully functional (shape + dtype +
//! bytes), so tensor round-trip code paths work without a backend.

use std::fmt;

/// Error type for all stub operations; implements `std::error::Error` so
/// `?` conversion into `anyhow::Error` works unchanged.
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }

    fn backend_unavailable(what: &str) -> Error {
        Error::new(format!(
            "xla backend unavailable in this build ({what}); \
             link the real PJRT bindings to execute artifacts"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla::Error({})", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// XLA primitive types used on the host boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
    U32,
}

impl PrimitiveType {
    fn element_type(self) -> ElementType {
        match self {
            PrimitiveType::F32 => ElementType::F32,
            PrimitiveType::S32 => ElementType::S32,
            PrimitiveType::U32 => ElementType::U32,
        }
    }

    fn size_bytes(self) -> usize {
        4
    }
}

/// Element types as reported by literal shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U32,
    Pred,
}

/// Scalar types that can cross the literal boundary.
pub trait NativeType: Copy + Default {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for u32 {}

/// Array shape metadata of a literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }
}

/// A host-side array literal (shape + dtype + raw little-endian bytes).
#[derive(Debug, Clone)]
pub struct Literal {
    ty: PrimitiveType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    /// Allocate a zero-initialized literal of the given shape.
    pub fn create_from_shape(ty: PrimitiveType, dims: &[usize]) -> Literal {
        let n: usize = dims.iter().product();
        Literal {
            ty,
            dims: dims.to_vec(),
            bytes: vec![0u8; n * ty.size_bytes()],
        }
    }

    /// Copy a raw host buffer into the literal (sizes must match).
    pub fn copy_raw_from<T: NativeType>(&mut self, src: &[T]) -> Result<()> {
        let want = self.bytes.len();
        let got = std::mem::size_of_val(src);
        if want != got {
            return Err(Error::new(format!(
                "copy_raw_from size mismatch: literal {want} bytes, source {got} bytes"
            )));
        }
        // SAFETY: NativeType is only implemented for plain-old-data scalars;
        // the byte lengths were checked above.
        let raw = unsafe { std::slice::from_raw_parts(src.as_ptr() as *const u8, got) };
        self.bytes.copy_from_slice(raw);
        Ok(())
    }

    /// Read the literal back as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        let elem = std::mem::size_of::<T>();
        if elem == 0 || self.bytes.len() % elem != 0 {
            return Err(Error::new("to_vec: element size does not divide buffer"));
        }
        let n = self.bytes.len() / elem;
        let mut out = vec![T::default(); n];
        // SAFETY: NativeType scalars are plain old data; lengths match.
        let raw =
            unsafe { std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, self.bytes.len()) };
        raw.copy_from_slice(&self.bytes);
        Ok(out)
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape {
            dims: self.dims.iter().map(|&d| d as i64).collect(),
            ty: self.ty.element_type(),
        })
    }

    /// Decompose a tuple literal. Stub literals are never tuples.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::backend_unavailable("tuple literals"))
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::backend_unavailable("HLO text parsing"))
    }
}

/// An XLA computation handle (opaque in the stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer returned by executions.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::backend_unavailable("buffer download"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::backend_unavailable("execution"))
    }
}

/// PJRT client handle. The stub cannot construct one.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::backend_unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::backend_unavailable("compilation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_gracefully() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_roundtrip() {
        let mut l = Literal::create_from_shape(PrimitiveType::F32, &[2, 3]);
        let data = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        l.copy_raw_from(&data).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), data);
        let shape = l.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.element_type(), ElementType::F32);
    }

    #[test]
    fn size_mismatch_rejected() {
        let mut l = Literal::create_from_shape(PrimitiveType::S32, &[4]);
        assert!(l.copy_raw_from(&[1i32, 2]).is_err());
    }
}
